//! Cluster shard-scaling acceptance bench: LeNet-5 train steps at
//! batch 32 across shards ∈ {1, 2, 4, 8, 16, 32, 64} modeled PIM chips
//! (shards=64 overshards the batch — 32 chips idle at zero priced
//! cost, exercising the empty-chunk path end to end).
//!
//! For every shard count it (a) runs one verified functional cluster
//! step and asserts its decomposed ledger equals the analytic
//! `cluster_step_cost` **exactly**, (b) benches the host wall-clock of
//! the step, and (c) records the *simulated* step latency.  The
//! acceptance gates — asserted in-binary, deterministic because they
//! are on simulated latency, not host wall — are that shards=4 cuts
//! step latency below 0.6× shards=1 and shards=64 below 0.05×
//! shards=1.  The shards=2 ≤ shards=1 *wall-clock* gate (the PR 7
//! anomaly fix) lives in `tools/check_bench_regression.py`, which reads
//! the emitted sidecar.
//!
//! Run: `cargo bench --bench cluster_scaling` (add `-- --json` for the
//! machine-readable `BENCH_cluster_scaling.json`; CI uploads the
//! sidecar and EXPERIMENTS.md §PR 3/§PR 7 track the numbers).

use mram_pim::arch::NetworkParams;
use mram_pim::bench::{bench, emit};
use mram_pim::cluster::{cluster_step_cost, ClusterConfig, ClusterEngine};
use mram_pim::data::Dataset;
use mram_pim::fpu::FpCostModel;
use mram_pim::model::Network;
use mram_pim::runtime::FUNCTIONAL_LANES;

fn main() {
    let net = Network::lenet5();
    let batch = 32usize;
    let data = Dataset::synthetic(batch, 0xC1).full_batch(batch);
    let model = FpCostModel::proposed_fp32();

    let mut results = Vec::new();
    let mut sim = Vec::new();
    for shards in [1usize, 2, 4, 8, 16, 32, 64] {
        let eng = ClusterEngine::new(model, FUNCTIONAL_LANES, ClusterConfig::new(shards, 1));

        // One verified step: the functional cluster ledger must equal
        // the analytic cluster_step_cost exactly (same constructor, so
        // equal integer counts imply bit-equal f64 terms).
        let mut p = NetworkParams::init(&net, 7);
        let r = eng
            .train_step(&net, &mut p, &data.images, &data.labels, batch, 0.05)
            .expect("cluster step");
        let cost =
            cluster_step_cost(&net, batch, shards, FUNCTIONAL_LANES, &model).expect("cost");
        assert_eq!(
            r.cost, cost,
            "functional cluster ledger drifted from cluster_step_cost at {shards} shards"
        );
        assert_eq!(r.waves, cost.total_waves());
        assert_eq!(r.total_macs(), net.training_work(batch).total_macs());
        println!(
            "shards {shards}: {} waves, sim latency {:.4e} s, energy {:.4e} J, \
             gradient merge {:.2}% of latency",
            r.waves,
            r.latency_s,
            r.energy_j,
            cost.reduce_overhead_frac() * 100.0
        );
        sim.push((shards, r.latency_s));

        results.push(bench(
            &format!("lenet5 cluster step batch {batch} shards {shards}"),
            1,
            4,
            || {
                let mut p = NetworkParams::init(&net, 7);
                let r = eng
                    .train_step(&net, &mut p, &data.images, &data.labels, batch, 0.05)
                    .expect("cluster step");
                std::hint::black_box(r.loss);
            },
        ));
    }

    emit("cluster_scaling", &results);

    // Acceptance gates (deterministic: simulated array latency).
    let sim_at = |want: usize| sim.iter().find(|&&(s, _)| s == want).expect("shard entry").1;
    let l1 = sim_at(1);
    let ratio4 = sim_at(4) / l1;
    assert!(
        ratio4 < 0.6,
        "acceptance: shards=4 step latency must be < 0.6x shards=1; got {ratio4:.3}x"
    );
    println!("shards=4 / shards=1 simulated step latency: {ratio4:.3}x  [acceptance: <0.6x]");
    let ratio64 = sim_at(64) / l1;
    assert!(
        ratio64 < 0.05,
        "acceptance: shards=64 step latency must be < 0.05x shards=1; got {ratio64:.4}x"
    );
    println!(
        "shards=64 / shards=1 simulated step latency: {ratio64:.4}x  [acceptance: <0.05x]"
    );
    println!("cluster_scaling OK");
}
