//! Host-performance bench of the subarray simulator's hot paths — the
//! target of the §Perf optimisation pass (EXPERIMENTS.md).  Simulated
//! (array) costs are constant; what this measures is how fast the
//! *simulator* runs on the host.
//!
//! Run: `cargo bench --bench subarray_hotpath`

use mram_pim::arch::GemmEngine;
use mram_pim::bench::{bench, emit, BenchResult};
use mram_pim::device::LogicOp;
use mram_pim::fpu::FloatFormat;
use mram_pim::nvsim::{ArrayGeometry, OpCosts};
use mram_pim::sim::Subarray;

fn main() {
    let geom = ArrayGeometry { rows: 1024, cols: 1024 };
    let costs = OpCosts::proposed_default();
    let mut results: Vec<BenchResult> = Vec::new();

    // Column ops over the full 1024-row height.
    let mut s = Subarray::new(geom, costs);
    results.push(bench("stateful XOR col (1024 rows)", 100, 50_000, || {
        s.stateful(LogicOp::Xor, 0, 1);
    }));

    let mut s2 = Subarray::new(geom, costs);
    results.push(bench("copy col (1024 rows)", 100, 50_000, || {
        s2.copy_col(2, 3);
    }));

    let mut s3 = Subarray::new(geom, costs);
    let key_cols: Vec<usize> = (10..18).collect();
    results.push(bench("8-col CAM search (1024 rows)", 100, 20_000, || {
        std::hint::black_box(s3.search_eq(&key_cols, 0x5A));
    }));

    let mut s4 = Subarray::new(geom, costs);
    let mask = vec![u64::MAX; s4.words_per_col()];
    results.push(bench("masked 28-col shift (1024 rows)", 100, 10_000, || {
        s4.masked_copy_shifted(&mask, 20, 28, 60, 28, 5);
    }));

    let mut s5 = Subarray::new(geom, costs);
    results.push(bench("write col w/ switch count", 100, 50_000, || {
        let data = vec![0xAAAA_AAAA_AAAA_AAAAu64; 16];
        s5.write_col(4, &data);
    }));

    // The throughput figure the perf pass optimises: simulated MACs/s.
    use mram_pim::fpu::procedure::FpEngine;
    let pairs: Vec<(u32, u32)> = (0..1024u32)
        .map(|i| (0x3F80_0000 + i * 7919, 0x4000_0000 + i * 104_729))
        .collect();
    let r = bench("full MAC wave: mul+add (1024 rows)", 1, 20, || {
        let mut e = FpEngine::new(
            ArrayGeometry { rows: 1024, cols: 256 },
            costs,
        );
        let p = e.mul(&pairs);
        let ps: Vec<(u32, u32)> = p.iter().map(|&x| (x, 0x3F00_0000)).collect();
        std::hint::black_box(e.add(&ps));
    });
    println!(
        "bit-level simulator throughput: {:.1}k MACs/s (host)",
        r.throughput(1024.0) / 1e3
    );
    results.push(r);

    // Functional-path counterpart: the batched GEMM engine's host
    // throughput (the §Perf headline next to the bit-level number).
    let engine = GemmEngine::new(costs, FloatFormat::FP32, 32_768, 4);
    let (out, inp, batch) = (128usize, 256usize, 32usize);
    let w: Vec<f32> = (0..out * inp)
        .map(|i| ((i % 13) as f32 - 6.0) * 0.31)
        .collect();
    let xb: Vec<f32> = (0..batch * inp)
        .map(|i| ((i % 19) as f32 - 9.0) * 0.23)
        .collect();
    let rg = bench("gemm engine wave 128x256 batch 32 (4 threads)", 1, 20, || {
        std::hint::black_box(engine.gemm(&w, &xb, None, out, inp, batch));
    });
    println!(
        "gemm engine throughput: {:.1}M MACs/s (host, 4 threads)",
        rg.throughput((out * inp * batch) as f64) / 1e6
    );
    results.push(rg);

    emit("subarray_hotpath", &results);
}
