//! Bench for paper Fig. 5 (E2/E3): fp32 MAC latency + energy, proposed
//! vs FloatPIM, with breakdown — regenerates the figure's numbers and
//! times the simulator paths that produce them.
//!
//! Run: `cargo bench --bench fig5_mac`

use mram_pim::arch::GemmEngine;
use mram_pim::bench::{bench, emit};
use mram_pim::floatpim::FloatPimCostModel;
use mram_pim::fpu::procedure::FpEngine;
use mram_pim::fpu::{FloatFormat, FpCostModel};
use mram_pim::nvsim::{ArrayGeometry, OpCosts};
use mram_pim::report;

fn main() {
    println!("{}", report::fig5());
    println!("{}", report::fast_switch());

    // CSV series for the figure.
    let ours = FpCostModel::proposed_fp32();
    let theirs = FloatPimCostModel::fp32_default();
    let tb = ours.t_mac_breakdown();
    let eb = ours.e_mac_breakdown();
    let rows = vec![
        vec![
            "proposed".into(),
            format!("{:.1}", ours.t_mac() * 1e9),
            format!("{:.2}", ours.e_mac() * 1e12),
            format!("{:.1}", tb.read * 1e9),
            format!("{:.1}", tb.write * 1e9),
            format!("{:.1}", tb.search * 1e9),
            format!("{:.2}", eb.read * 1e12),
            format!("{:.2}", eb.write * 1e12),
            format!("{:.2}", eb.search * 1e12),
        ],
        vec![
            "floatpim".into(),
            format!("{:.1}", theirs.t_mac() * 1e9),
            format!("{:.2}", theirs.e_mac() * 1e12),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ],
    ];
    let _ = report::write_csv(
        "target/fig5_mac.csv",
        "design,latency_ns,energy_pj,t_read_ns,t_write_ns,t_search_ns,e_read_pj,e_write_pj,e_search_pj",
        &rows,
    );
    println!("wrote target/fig5_mac.csv");

    // Host-side timing: how fast the simulator itself evaluates.
    let mut results = Vec::new();
    results.push(bench("analytic mac cost (ours)", 100, 10_000, || {
        let m = FpCostModel::proposed_fp32();
        std::hint::black_box((m.t_mac(), m.e_mac()));
    }));
    results.push(bench("analytic mac cost (floatpim)", 100, 10_000, || {
        let m = FloatPimCostModel::fp32_default();
        std::hint::black_box((m.t_mac(), m.e_mac()));
    }));
    let pairs: Vec<(u32, u32)> = (0..1024u32)
        .map(|i| (0x3F80_0000 + i * 7919, 0x4000_0000 + i * 104_729))
        .collect();
    results.push(bench("bit-level mul wave (1024 rows)", 1, 20, || {
        let mut e = FpEngine::new(
            ArrayGeometry { rows: 1024, cols: 256 },
            OpCosts::proposed_default(),
        );
        std::hint::black_box(e.mul(&pairs));
    }));
    results.push(bench("bit-level add wave (1024 rows)", 1, 20, || {
        let mut e = FpEngine::new(
            ArrayGeometry { rows: 1024, cols: 256 },
            OpCosts::proposed_default(),
        );
        std::hint::black_box(e.add(&pairs));
    }));

    // The functional hot path: MAC waves through the batched GEMM
    // engine (cached cost model, softfloat fast path, 4 host threads).
    let engine = GemmEngine::new(OpCosts::proposed_default(), FloatFormat::FP32, 32_768, 4);
    let (out, inp, batch) = (64usize, 128usize, 32usize);
    let w: Vec<f32> = (0..out * inp)
        .map(|i| ((i % 17) as f32 - 8.0) * 0.37)
        .collect();
    let xb: Vec<f32> = (0..batch * inp)
        .map(|i| ((i % 23) as f32 - 11.0) * 0.19)
        .collect();
    results.push(bench("gemm engine 64x128 batch 32 (threads 4)", 2, 50, || {
        std::hint::black_box(engine.gemm(&w, &xb, None, out, inp, batch));
    }));

    emit("fig5_mac", &results);
}
