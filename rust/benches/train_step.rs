//! Functional train-step bench + the PR 5 blocked-kernel acceptance
//! gate.
//!
//! Benches LeNet-5 fwd+bwd+update through the wave-parallel train
//! engine in three execution modes:
//!
//! * **pooled** — the PR 5 steady-state engine: blocked layout-aware
//!   kernels (NT/NN/TN), pre-decoded weight panels, transpose-free
//!   backward, persistent worker pool, scratch-arena recycling;
//! * **flat** — the frozen PR 4 steady-state floor (`ExecMode::Flat`):
//!   same pool and arena, but the flat per-MAC-decode row loop and the
//!   transpose-based backward lowering;
//! * **scoped** — the frozen PR 3 *execution shape* (fresh
//!   `thread::scope` workers per GEMM, fresh allocations), reported for
//!   the long-term trajectory.  Note: since the PR 5 inner-loop dedupe
//!   it shares the flat shortcut-chain loop with the Flat floor, so its
//!   wall-clock is a touch faster than the PR 3 engine literally
//!   shipped — the spawn/alloc behaviour is what this mode freezes.
//!
//! In-binary gates: the blocked pooled engine must beat the flat PR 4
//! floor by **≥1.3× mean wall-clock** at batch 32 / threads 4
//! (`TRAIN_STEP_MIN_SPEEDUP` overrides the floor for noisy runners; CI
//! uses a relaxed value), a steady-state step in *either* pooled mode
//! must perform **zero heap allocations** (counting global allocator;
//! `TRAIN_STEP_ALLOC_TOLERANCE` overrides), **zero thread spawns**
//! (the pool's launch counter) and — since PR 8 — **zero weight-panel
//! decode passes** (`arch::panel_decodes`; the decoded u64 panel is the
//! *resident* weight format, rebuilt only when the f32 mirror changes
//! under the engine, so a steady step re-decodes nothing), the pooled
//! and flat engines must produce bit-identical losses and updated
//! weights, and the ledger must equal the analytic `training_work`
//! exactly.  The decode count is also emitted as a `metric:` JSON entry
//! with an exact baseline of 0, so CI's bench-regression gate fails if
//! a future change quietly reintroduces per-step decoding.
//!
//! Also reports the forward-only pass for the fwd:bwd:update split that
//! EXPERIMENTS.md compares against Fig. 6.
//!
//! Run: `cargo bench --bench train_step` (add `-- --json` for the
//! machine-readable `BENCH_train_step.json`; CI uploads the sidecar and
//! `tools/check_bench_regression.py` diffs it against the committed
//! baseline).

use mram_pim::arch::pool::worker_launches;
use mram_pim::arch::{panel_decodes, ExecMode, NetworkParams, TrainEngine};
use mram_pim::bench::{bench, emit, heap_allocations, BenchResult, CountingAllocator};
use mram_pim::data::Dataset;
use mram_pim::fpu::FpCostModel;
use mram_pim::model::Network;
use mram_pim::prop::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Warm the engine, then measure allocations + spawns + weight-panel
/// decode passes of one steady step; returns (allocs, spawns, decodes,
/// loss).  The decode counter is thread-local to this (dispatching)
/// thread, which is exactly where both resident rebuilds and transient
/// per-call panel passes are accounted.
fn steady_audit(
    eng: &TrainEngine,
    net: &Network,
    images: &[f32],
    labels: &[i32],
    batch: usize,
) -> (u64, u64, u64, f32) {
    let mut p = NetworkParams::init(net, 7);
    for _ in 0..2 {
        let r = eng
            .train_step(net, &mut p, images, labels, batch, 0.05)
            .expect("warm step");
        eng.recycle(r);
    }
    let spawns0 = worker_launches();
    let allocs0 = heap_allocations();
    let decodes0 = panel_decodes();
    let r = eng
        .train_step(net, &mut p, images, labels, batch, 0.05)
        .expect("steady step");
    let loss = r.loss;
    eng.recycle(r);
    (
        heap_allocations() - allocs0,
        worker_launches() - spawns0,
        panel_decodes() - decodes0,
        loss,
    )
}

fn main() {
    let net = Network::lenet5();
    let batch = 32usize;
    let mut rng = Rng::new(0x7EA1);
    let data = Dataset::synthetic(batch, 0x7EA1).full_batch(batch);
    let labels: Vec<i32> = data.labels.clone();
    // Jitter the images slightly per run so no engine sees frozen
    // activations the branch predictor could memorise.  (This also
    // de-sparsifies the input pixels, which makes the measurement
    // *conservative* for the zero-operand MAC shortcut: only genuine
    // ReLU/mask zeros inside the network still skip.)
    let images: Vec<f32> = data
        .images
        .iter()
        .map(|&v| v + rng.f32_normal(1) * 1e-6)
        .collect();

    let work = net.training_work(batch);
    let mut results = Vec::new();

    let pooled1 = TrainEngine::new(FpCostModel::proposed_fp32(), 32_768, 1);
    let pooled4 = TrainEngine::new(FpCostModel::proposed_fp32(), 32_768, 4);
    let flat4 = TrainEngine::new_mode(FpCostModel::proposed_fp32(), 32_768, 4, ExecMode::Flat);
    let scoped4 = TrainEngine::new_mode(
        FpCostModel::proposed_fp32(),
        32_768,
        4,
        ExecMode::Scoped,
    );

    // Forward-only (inference) pass for the phase split.
    let params = NetworkParams::init(&net, 7);
    let r_fwd = bench(
        &format!("lenet5 forward batch {batch} (threads 4, pooled)"),
        1,
        8,
        || {
            let r = pooled4.gemm().forward(&net, &params, &images, batch);
            std::hint::black_box(r.macs);
            pooled4.gemm().recycle_buf(r.y);
        },
    );

    // Full train step per mode.  Each iteration trains from a fresh
    // init so the work is identical across iterations; the pool/arena
    // loops recycle results (the steady-state contract), the scoped
    // loop drops them (PR 3 had nothing to recycle into).
    let r1 = bench(
        &format!("lenet5 train step batch {batch} (threads 1, pooled)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = pooled1
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
            pooled1.recycle(r);
        },
    );
    let r4 = bench(
        &format!("lenet5 train step batch {batch} (threads 4, pooled)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = pooled4
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
            pooled4.recycle(r);
        },
    );
    let rf = bench(
        &format!("lenet5 train step batch {batch} (threads 4, flat PR4 baseline)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = flat4
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
            flat4.recycle(r);
        },
    );
    let spawns_before_scoped = worker_launches();
    let rs = bench(
        &format!("lenet5 train step batch {batch} (threads 4, scoped PR3 baseline)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = scoped4
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
        },
    );
    let scoped_spawns = (worker_launches() - spawns_before_scoped) as f64 / 7.0; // warmup + 6 iters

    // ---- steady-state allocation + spawn audit: the blocked engine
    //      and the flat floor must both be clean, so the speedup below
    //      is a kernel comparison, not an allocator artifact ----
    let (pooled_allocs, pooled_spawns, pooled_decodes, loss_pooled) =
        steady_audit(&pooled4, &net, &images, &labels, batch);
    let (flat_allocs, flat_spawns, flat_decodes, loss_flat) =
        steady_audit(&flat4, &net, &images, &labels, batch);
    assert_eq!(
        loss_pooled.to_bits(),
        loss_flat.to_bits(),
        "blocked kernels drifted from the PR 4 floor"
    );

    // One verified step per mode: bit-identical losses and updated
    // weights, ledger equal to the analytic model.
    let mut p_pooled = NetworkParams::init(&net, 7);
    let step = pooled4
        .train_step(&net, &mut p_pooled, &images, &labels, batch, 0.05)
        .expect("train step");
    assert_eq!(step.total_macs(), work.total_macs(), "ledger drifted");
    assert_eq!(step.macs_bwd, 2 * step.macs_fwd);
    let mut p_flat = NetworkParams::init(&net, 7);
    let step_flat = flat4
        .train_step(&net, &mut p_flat, &images, &labels, batch, 0.05)
        .expect("train step");
    assert_eq!(step.loss.to_bits(), step_flat.loss.to_bits());
    assert_eq!(step.waves, step_flat.waves);
    for (a, b) in p_pooled.layers.iter().flatten().zip(p_flat.layers.iter().flatten()) {
        for (x, y) in a.w.iter().zip(&b.w) {
            assert!(x.to_bits() == y.to_bits(), "updated weights diverged");
        }
    }
    flat4.recycle(step_flat);

    let macs = work.total_macs() as f64;
    let speedup = rf.mean_ns / r4.mean_ns;
    let speedup_scoped = rs.mean_ns / r4.mean_ns;
    println!(
        "host throughput: {:.1}M train MACs/s (threads 4, pooled); fwd:bwd:update MAC split = 1 : {:.2} : {:.4}",
        r4.throughput(macs) / 1e6,
        step.macs_bwd as f64 / step.macs_fwd as f64,
        step.macs_wu as f64 / step.macs_fwd as f64,
    );
    println!(
        "simulated per-step cost: {} waves, latency {:.3e} s, energy {:.3e} J",
        step.waves, step.latency_s, step.energy_j
    );
    println!(
        "train step vs forward-only (threads 4): {:.2}x host wall (MAC model predicts ~3x + host bwd overheads)",
        r4.mean_ns / r_fwd.mean_ns
    );
    println!(
        "steady-state audit: pooled {pooled_allocs} allocs / {pooled_spawns} spawns / \
         {pooled_decodes} panel decodes, \
         flat floor {flat_allocs} allocs / {flat_spawns} spawns / {flat_decodes} decodes per step; \
         scoped baseline spawns {scoped_spawns:.0} threads/step"
    );
    println!(
        "blocked kernels vs flat PR4 floor @ batch {batch} threads 4: {speedup:.2}x  \
         [acceptance: >=1.3x]; vs scoped PR3 baseline: {speedup_scoped:.2}x"
    );

    results.push(r_fwd);
    results.push(r1);
    results.push(r4);
    results.push(rf);
    results.push(rs);
    // PR 8 resident-panel counter, emitted as an exact `metric:` entry
    // (value in `mean_ns`, baseline 0.0): the regression gate treats any
    // fresh value above the committed 0 as a hard failure.
    let d = pooled_decodes as f64;
    results.push(BenchResult {
        name: "metric: decodes per step (threads 4, pooled)".into(),
        iters: 1,
        mean_ns: d,
        p50_ns: d,
        p99_ns: d,
        min_ns: d,
    });
    emit("train_step", &results);

    // ---- acceptance gates ----
    let min_speedup = env_f64("TRAIN_STEP_MIN_SPEEDUP", 1.3);
    assert!(
        speedup >= min_speedup,
        "acceptance: blocked-kernel pooled engine must be >={min_speedup}x the flat PR4 \
         pooled floor at batch 32 with threads = 4; measured {speedup:.2}x"
    );
    let alloc_tolerance = env_f64("TRAIN_STEP_ALLOC_TOLERANCE", 0.0) as u64;
    for (who, allocs, spawns) in [
        ("pooled", pooled_allocs, pooled_spawns),
        ("flat floor", flat_allocs, flat_spawns),
    ] {
        assert!(
            allocs <= alloc_tolerance,
            "acceptance: steady-state {who} train step must not touch the heap \
             (measured {allocs} allocations, tolerance {alloc_tolerance})"
        );
        assert_eq!(
            spawns, 0,
            "acceptance: steady-state {who} train step must not spawn threads"
        );
    }
    for (who, decodes) in [("pooled", pooled_decodes), ("flat floor", flat_decodes)] {
        assert_eq!(
            decodes, 0,
            "acceptance: steady-state {who} train step must not re-decode weight \
             panels (resident-panel contract; measured {decodes} bulk decode passes)"
        );
    }
    println!("train_step OK");
}
