//! Functional train-step bench + the PR 4 steady-state acceptance gate.
//!
//! Benches LeNet-5 fwd+bwd+update through the wave-parallel train
//! engine in both execution modes:
//!
//! * **pooled** — persistent worker pool, scratch-arena recycling,
//!   zero-operand MAC shortcut (the steady-state engine), and
//! * **scoped** — the frozen PR 3 baseline (fresh `thread::scope`
//!   workers per GEMM, fresh allocations per buffer, plain MAC chain),
//!
//! and asserts in-binary that the pooled engine beats the scoped
//! baseline by ≥1.5× mean wall-clock at batch 32 / threads 4
//! (`TRAIN_STEP_MIN_SPEEDUP` overrides the floor for noisy runners),
//! that a steady-state pooled step performs **zero heap allocations**
//! (counting global allocator; `TRAIN_STEP_ALLOC_TOLERANCE` overrides),
//! and **zero thread spawns** (the pool's launch counter).
//!
//! Also reports the forward-only pass for the fwd:bwd:update split that
//! EXPERIMENTS.md compares against Fig. 6's phase ratios.
//!
//! Run: `cargo bench --bench train_step` (add `-- --json` for the
//! machine-readable `BENCH_train_step.json`; CI uploads the sidecar and
//! `tools/check_bench_regression.py` diffs it against the committed
//! baseline).

use mram_pim::arch::pool::worker_launches;
use mram_pim::arch::{ExecMode, NetworkParams, TrainEngine};
use mram_pim::bench::{bench, emit, heap_allocations, CountingAllocator};
use mram_pim::data::Dataset;
use mram_pim::fpu::FpCostModel;
use mram_pim::model::Network;
use mram_pim::prop::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let net = Network::lenet5();
    let batch = 32usize;
    let mut rng = Rng::new(0x7EA1);
    let data = Dataset::synthetic(batch, 0x7EA1).full_batch(batch);
    let labels: Vec<i32> = data.labels.clone();
    // Jitter the images slightly per run so no engine sees frozen
    // activations the branch predictor could memorise.  (This also
    // de-sparsifies the input pixels, which makes the measurement
    // *conservative* for the zero-operand MAC shortcut: only genuine
    // ReLU/mask zeros inside the network still skip.)
    let images: Vec<f32> = data
        .images
        .iter()
        .map(|&v| v + rng.f32_normal(1) * 1e-6)
        .collect();

    let work = net.training_work(batch);
    let mut results = Vec::new();

    let pooled1 = TrainEngine::new(FpCostModel::proposed_fp32(), 32_768, 1);
    let pooled4 = TrainEngine::new(FpCostModel::proposed_fp32(), 32_768, 4);
    let scoped4 = TrainEngine::new_mode(
        FpCostModel::proposed_fp32(),
        32_768,
        4,
        ExecMode::Scoped,
    );

    // Forward-only (inference) pass for the phase split.
    let params = NetworkParams::init(&net, 7);
    let r_fwd = bench(
        &format!("lenet5 forward batch {batch} (threads 4, pooled)"),
        1,
        8,
        || {
            let r = pooled4.gemm().forward(&net, &params, &images, batch);
            std::hint::black_box(r.macs);
            pooled4.gemm().recycle_buf(r.y);
        },
    );

    // Full train step: pooled threads 1 / 4, scoped threads 4 (the PR 3
    // baseline).  Each iteration trains from a fresh init so the work
    // is identical across iterations; the pooled loops recycle results
    // (the steady-state contract), the scoped loop drops them (PR 3
    // had nothing to recycle into).
    let r1 = bench(
        &format!("lenet5 train step batch {batch} (threads 1, pooled)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = pooled1
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
            pooled1.recycle(r);
        },
    );
    let spawns_before_pooled = worker_launches();
    let r4 = bench(
        &format!("lenet5 train step batch {batch} (threads 4, pooled)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = pooled4
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
            pooled4.recycle(r);
        },
    );
    let pooled_spawns = worker_launches() - spawns_before_pooled;
    let spawns_before_scoped = worker_launches();
    let rs = bench(
        &format!("lenet5 train step batch {batch} (threads 4, scoped PR3 baseline)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = scoped4
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
        },
    );
    let scoped_spawns = (worker_launches() - spawns_before_scoped) as f64 / 7.0; // warmup + 6 iters

    // ---- steady-state allocation + spawn audit (pooled engine) ----
    let mut p = NetworkParams::init(&net, 7);
    for _ in 0..2 {
        let r = pooled4
            .train_step(&net, &mut p, &images, &labels, batch, 0.05)
            .expect("warm step");
        pooled4.recycle(r);
    }
    let spawns0 = worker_launches();
    let allocs0 = heap_allocations();
    let r = pooled4
        .train_step(&net, &mut p, &images, &labels, batch, 0.05)
        .expect("steady step");
    let loss_steady = r.loss;
    pooled4.recycle(r);
    let steady_allocs = heap_allocations() - allocs0;
    let steady_spawns = worker_launches() - spawns0;
    std::hint::black_box(loss_steady);

    // One verified step for the ledger numbers the table quotes.
    let mut p = NetworkParams::init(&net, 7);
    let step = pooled4
        .train_step(&net, &mut p, &images, &labels, batch, 0.05)
        .expect("train step");
    assert_eq!(step.total_macs(), work.total_macs(), "ledger drifted");
    assert_eq!(step.macs_bwd, 2 * step.macs_fwd);

    let macs = work.total_macs() as f64;
    let speedup = rs.mean_ns / r4.mean_ns;
    println!(
        "host throughput: {:.1}M train MACs/s (threads 4, pooled); fwd:bwd:update MAC split = 1 : {:.2} : {:.4}",
        r4.throughput(macs) / 1e6,
        step.macs_bwd as f64 / step.macs_fwd as f64,
        step.macs_wu as f64 / step.macs_fwd as f64,
    );
    println!(
        "simulated per-step cost: {} waves, latency {:.3e} s, energy {:.3e} J",
        step.waves, step.latency_s, step.energy_j
    );
    println!(
        "train step vs forward-only (threads 4): {:.2}x host wall (MAC model predicts ~3x + host bwd overheads)",
        r4.mean_ns / r_fwd.mean_ns
    );
    println!(
        "steady-state audit: {steady_allocs} heap allocations, {steady_spawns} thread spawns per pooled step \
         (timed pooled loop spawned {pooled_spawns}); scoped baseline spawns {scoped_spawns:.0} threads/step"
    );
    println!(
        "pooled vs scoped PR3 baseline @ batch {batch} threads 4: {speedup:.2}x  [acceptance: >=1.5x]"
    );

    results.push(r_fwd);
    results.push(r1);
    results.push(r4);
    results.push(rs);
    emit("train_step", &results);

    // ---- acceptance gates ----
    let min_speedup = env_f64("TRAIN_STEP_MIN_SPEEDUP", 1.5);
    assert!(
        speedup >= min_speedup,
        "acceptance: pooled steady-state engine must be >={min_speedup}x the scoped PR3 \
         baseline at batch 32 with threads = 4; measured {speedup:.2}x"
    );
    let alloc_tolerance = env_f64("TRAIN_STEP_ALLOC_TOLERANCE", 0.0) as u64;
    assert!(
        steady_allocs <= alloc_tolerance,
        "acceptance: steady-state pooled train step must not touch the heap \
         (measured {steady_allocs} allocations, tolerance {alloc_tolerance})"
    );
    assert_eq!(
        steady_spawns, 0,
        "acceptance: steady-state pooled train step must not spawn threads"
    );
    println!("train_step OK");
}
