//! Functional train-step bench: LeNet-5 fwd+bwd+update through the
//! wave-parallel train engine, plus the forward-only pass for the
//! fwd:bwd:update split that EXPERIMENTS.md compares against Fig. 6's
//! phase ratios.
//!
//! Run: `cargo bench --bench train_step` (add `-- --json` for the
//! machine-readable `BENCH_train_step.json`; CI uploads the sidecar).

use mram_pim::arch::{NetworkParams, TrainEngine};
use mram_pim::bench::{bench, emit};
use mram_pim::data::Dataset;
use mram_pim::fpu::FpCostModel;
use mram_pim::model::Network;
use mram_pim::prop::Rng;

fn main() {
    let net = Network::lenet5();
    let batch = 32usize;
    let mut rng = Rng::new(0x7EA1);
    let data = Dataset::synthetic(batch, 0x7EA1).full_batch(batch);
    let labels: Vec<i32> = data.labels.clone();
    // Jitter the images slightly per engine so no engine sees frozen
    // activations the branch predictor could memorise.
    let images: Vec<f32> = data
        .images
        .iter()
        .map(|&v| v + rng.f32_normal(1) * 1e-6)
        .collect();

    let work = net.training_work(batch);
    let mut results = Vec::new();

    let e1 = TrainEngine::new(FpCostModel::proposed_fp32(), 32_768, 1);
    let e4 = TrainEngine::new(FpCostModel::proposed_fp32(), 32_768, 4);

    // Forward-only (inference) pass for the phase split.
    let params = NetworkParams::init(&net, 7);
    let r_fwd = bench(
        &format!("lenet5 forward batch {batch} (threads 4)"),
        1,
        8,
        || {
            std::hint::black_box(e4.gemm().forward(&net, &params, &images, batch));
        },
    );

    // Full train step, threads 1 and 4.  Each iteration trains from a
    // fresh init so the work is identical across iterations.
    let r1 = bench(
        &format!("lenet5 train step batch {batch} (threads 1)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = e1
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
        },
    );
    let r4 = bench(
        &format!("lenet5 train step batch {batch} (threads 4)"),
        1,
        6,
        || {
            let mut p = NetworkParams::init(&net, 7);
            let r = e4
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
        },
    );

    // One verified step for the ledger numbers the table quotes.
    let mut p = NetworkParams::init(&net, 7);
    let step = e4
        .train_step(&net, &mut p, &images, &labels, batch, 0.05)
        .expect("train step");
    assert_eq!(step.total_macs(), work.total_macs(), "ledger drifted");
    assert_eq!(step.macs_bwd, 2 * step.macs_fwd);

    let macs = work.total_macs() as f64;
    println!(
        "host throughput: {:.1}M train MACs/s (threads 4); fwd:bwd:update MAC split = 1 : {:.2} : {:.4}",
        r4.throughput(macs) / 1e6,
        step.macs_bwd as f64 / step.macs_fwd as f64,
        step.macs_wu as f64 / step.macs_fwd as f64,
    );
    println!(
        "simulated per-step cost: {} waves, latency {:.3e} s, energy {:.3e} J",
        step.waves, step.latency_s, step.energy_j
    );
    println!(
        "train step vs forward-only (threads 4): {:.2}x host wall (MAC model predicts ~3x + host bwd overheads)",
        r4.mean_ns / r_fwd.mean_ns
    );

    results.push(r_fwd);
    results.push(r1);
    results.push(r4);
    emit("train_step", &results);
    println!("train_step OK");
}
