//! Wave-parallel GEMM engine vs the seed scalar path — the acceptance
//! bench for the batched-engine PR: at batch 32 with `threads = 4`, the
//! engine must beat the seed's single-threaded per-call-model scalar
//! GEMV loop by ≥5× mean latency, while `rust/tests/properties.rs`
//! proves the results bit-unchanged.
//!
//! Run: `cargo bench --bench gemm_wave` (add `-- --json` for the
//! machine-readable `BENCH_gemm_wave.json`; numbers land in
//! EXPERIMENTS.md §Perf).

use mram_pim::arch::GemmEngine;
use mram_pim::bench::{bench, emit};
use mram_pim::fpu::softfloat::{pim_add_f32, pim_mul_f32};
use mram_pim::fpu::{FloatFormat, FpCostModel};
use mram_pim::model::Layer;
use mram_pim::nvsim::OpCosts;
use mram_pim::prop::Rng;

/// The seed's scalar `pim_gemv` hot path, frozen verbatim as the perf
/// baseline: cost model rebuilt on every call, an ungrown output `Vec`,
/// and one scalar two-rounding MAC chain per element on one thread.
fn seed_scalar_gemv(w: &[f32], x: &[f32], out: usize, inp: usize) -> (Vec<f32>, f64, f64) {
    let model = FpCostModel::new(OpCosts::proposed_default(), FloatFormat::FP32);
    let mut y = Vec::new();
    for o in 0..out {
        let mut acc = 0.0f32;
        for i in 0..inp {
            acc = pim_add_f32(acc, pim_mul_f32(w[o * inp + i], x[i]));
        }
        y.push(acc);
    }
    (y, model.t_mac(), model.e_mac())
}

fn main() {
    let (out, inp, batch) = (128usize, 256usize, 32usize);
    let mut rng = Rng::new(0x6E44);
    let w: Vec<f32> = (0..out * inp).map(|_| rng.f32_normal(4)).collect();
    let xb: Vec<f32> = (0..batch * inp).map(|_| rng.f32_normal(4)).collect();

    let mut results = Vec::new();

    let r_seed = bench(
        &format!("seed scalar gemv x{batch} ({out}x{inp})"),
        1,
        10,
        || {
            for b in 0..batch {
                std::hint::black_box(seed_scalar_gemv(
                    &w,
                    &xb[b * inp..(b + 1) * inp],
                    out,
                    inp,
                ));
            }
        },
    );

    let e1 = GemmEngine::new(OpCosts::proposed_default(), FloatFormat::FP32, 32_768, 1);
    let e4 = GemmEngine::new(OpCosts::proposed_default(), FloatFormat::FP32, 32_768, 4);
    let r1 = bench(
        &format!("gemm engine {out}x{inp} batch {batch} (threads 1)"),
        1,
        10,
        || {
            std::hint::black_box(e1.gemm(&w, &xb, None, out, inp, batch));
        },
    );
    let r4 = bench(
        &format!("gemm engine {out}x{inp} batch {batch} (threads 4)"),
        1,
        10,
        || {
            std::hint::black_box(e4.gemm(&w, &xb, None, out, inp, batch));
        },
    );

    // The backward layouts on the same operands (PR 5): dgrad reads W
    // by k-rows (NN), wgrad reads both operands by k-rows (TN) — the
    // transpose-free kernels the training engine now lowers onto.
    let delta: Vec<f32> = (0..batch * out).map(|_| rng.f32_normal(2)).collect();
    let r_nn = bench(
        &format!("gemm nn dgrad {inp}x{out} batch {batch} (threads 4)"),
        1,
        10,
        || {
            // dX = δ·W: [batch, out] × [out, inp]
            std::hint::black_box(e4.gemm_nn(&delta, &w, batch, out, inp));
        },
    );
    let r_tn = bench(
        &format!("gemm tn wgrad {out}x{inp} batch {batch} (threads 4)"),
        1,
        10,
        || {
            // dW = δᵀ·X: [batch, out]ᵀ × [batch, inp]
            std::hint::black_box(e4.gemm_tn(&delta, &xb, out, batch, inp));
        },
    );

    // Conv2d through the same engine (LeNet conv2 shape, im2col lowering).
    let conv = Layer::Conv2d {
        in_ch: 6,
        out_ch: 12,
        kh: 5,
        kw: 5,
        in_h: 12,
        in_w: 12,
    };
    let cw: Vec<f32> = (0..12 * 6 * 5 * 5).map(|_| rng.f32_normal(2)).collect();
    let cb: Vec<f32> = (0..12).map(|_| rng.f32_normal(1)).collect();
    let cx: Vec<f32> = (0..batch * 6 * 12 * 12).map(|_| rng.f32_normal(2)).collect();
    let r_conv = bench(
        &format!("conv2d im2col 6->12 5x5 batch {batch} (threads 4)"),
        1,
        10,
        || {
            std::hint::black_box(e4.conv2d(&conv, &cw, Some(&cb), &cx, batch));
        },
    );

    let speedup_1t = r_seed.mean_ns / r1.mean_ns;
    let speedup_4t = r_seed.mean_ns / r4.mean_ns;
    let total_macs = (out * inp * batch) as f64;
    println!(
        "engine throughput: {:.1}M MACs/s (threads 4, host)",
        r4.throughput(total_macs) / 1e6
    );
    println!(
        "speedup over seed scalar path @ batch {batch}: {speedup_1t:.1}x (threads 1), \
         {speedup_4t:.1}x (threads 4)  [acceptance: >=5x]"
    );

    results.push(r_seed);
    results.push(r1);
    results.push(r4);
    results.push(r_nn);
    results.push(r_tn);
    results.push(r_conv);
    emit("gemm_wave", &results);

    // Acceptance gate: >=5x by default; overridable (e.g. a lower floor
    // on noisy shared CI runners via GEMM_WAVE_MIN_SPEEDUP=3).
    let min_speedup: f64 = std::env::var("GEMM_WAVE_MIN_SPEEDUP")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5.0);
    assert!(
        speedup_4t >= min_speedup,
        "acceptance: engine must be >={min_speedup}x the seed scalar path at \
         batch 32 with threads = 4; measured {speedup_4t:.2}x"
    );
    println!("gemm_wave OK");
}
