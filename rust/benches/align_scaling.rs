//! Bench for the §3.3 exponent-alignment claim (E7): O(Nm) for the
//! proposed search-based scheme vs O(Nm²) for FloatPIM's bit-by-bit
//! shifting — swept over mantissa width.
//!
//! Run: `cargo bench --bench align_scaling`

use mram_pim::bench::{bench, emit};
use mram_pim::floatpim::FloatPimCostModel;
use mram_pim::fpu::procedure::FpEngine;
use mram_pim::fpu::{FloatFormat, FpCostModel};
use mram_pim::nvsim::{ArrayGeometry, OpCosts};
use mram_pim::report;

fn main() {
    println!("exponent-alignment scaling (add-path steps vs mantissa bits):\n");
    println!(
        "{:>4} {:>18} {:>22} {:>8}",
        "Nm", "ours (searches)", "floatpim (switches)", "ratio"
    );
    let mut rows = Vec::new();
    for nm in [4u32, 8, 10, 16, 23, 32, 40, 52] {
        let ours = FpCostModel::new(
            OpCosts::proposed_default(),
            FloatFormat { ne: 8, nm },
        );
        let theirs = FloatPimCostModel::new(Default::default(), FloatFormat { ne: 8, nm });
        let o = ours.add_search_steps();
        let f = theirs.add_switch_steps();
        println!("{nm:>4} {o:>18.0} {f:>22.0} {:>7.1}x", f / o);
        rows.push(vec![
            nm.to_string(),
            format!("{o:.0}"),
            format!("{f:.0}"),
            format!("{:.2}", f / o),
        ]);
    }
    let _ = report::write_csv(
        "target/align_scaling.csv",
        "nm,ours_search_steps,floatpim_switch_steps,ratio",
        &rows,
    );
    println!("\nwrote target/align_scaling.csv");
    println!("(linear vs quadratic: the gap widens with every extra mantissa bit)\n");

    // Executable check: the engine's actual search count at fp32, plus
    // host wall-clock for the full add wave.
    let pairs: Vec<(u32, u32)> = (0..1024u32)
        .map(|i| (0x3F80_0000 + i * 31, 0x4100_0000 + i * 17))
        .collect();
    let mut e = FpEngine::new(
        ArrayGeometry { rows: 1024, cols: 256 },
        OpCosts::proposed_default(),
    );
    e.add(&pairs);
    println!(
        "executed fp32 add wave: {} searches (analytic 2(Nm+2) = 50)",
        e.sub.ledger.searches
    );

    let results = vec![bench("fp32 add wave w/ alignment (1024 rows)", 1, 20, || {
        let mut e = FpEngine::new(
            ArrayGeometry { rows: 1024, cols: 256 },
            OpCosts::proposed_default(),
        );
        std::hint::black_box(e.add(&pairs));
    })];
    emit("align_scaling", &results);
}
