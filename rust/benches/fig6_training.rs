//! Bench for paper Fig. 6 (E4): LeNet-5 training area/latency/energy,
//! proposed vs FloatPIM, normalised — plus the model-size ablation and
//! the end-to-end simulator timing the §Perf pass tracks.
//!
//! Run: `cargo bench --bench fig6_training`

use mram_pim::arch::{AccelKind, Accelerator};
use mram_pim::bench::{bench, emit};
use mram_pim::fpu::FloatFormat;
use mram_pim::model::Network;
use mram_pim::report;

fn main() {
    println!("{}", report::fig6(300));

    // CSV for the figure (normalised bars).
    let net = Network::lenet5();
    let ours = Accelerator::new(AccelKind::Proposed, FloatFormat::FP32, 32_768);
    let fpim = Accelerator::new(AccelKind::FloatPim, FloatFormat::FP32, 32_768);
    let o = ours.training_cost(&net, 32, 300);
    let f = fpim.training_cost(&net, 32, 300);
    let rows = vec![vec![
        "lenet5".into(),
        format!("{:.3}", f.area_m2 / o.area_m2),
        format!("{:.3}", f.latency_s / o.latency_s),
        format!("{:.3}", f.energy_j / o.energy_j),
    ]];
    let _ = report::write_csv(
        "target/fig6_training.csv",
        "model,area_ratio,latency_ratio,energy_ratio",
        &rows,
    );
    println!("wrote target/fig6_training.csv");

    // Scalability ablation (§5 future work): same ratios on bigger nets.
    println!("model-size ablation (energy/latency/area ratios vs FloatPIM):");
    for net in [Network::lenet5(), Network::lenet_300_100(), Network::cnn_medium()] {
        let o = ours.train_step_cost(&net, 32);
        let f = fpim.train_step_cost(&net, 32);
        println!(
            "  {:<16} E {:.2}x  T {:.2}x  A {:.2}x",
            net.name,
            f.energy_j / o.energy_j,
            f.latency_s / o.latency_s,
            f.area_m2 / o.area_m2
        );
    }

    // Pipelined-deployment ablation: how much of Fig. 6's latency a
    // PipeLayer-style layer pipeline recovers (arch::schedule).
    use mram_pim::arch::PipelineSchedule;
    println!("\npipeline ablation (LeNet-5, batch 32, 300 batches in flight):");
    let sched = PipelineSchedule::build(&ours, &Network::lenet5(), 32, 300);
    println!(
        "  stages {}  bottleneck {:.2} ms  serial {:.2} s  pipelined {:.2} s  speedup {:.2}x  util {:.0}%",
        sched.stages,
        sched.bottleneck_s() * 1e3,
        sched.serial_s(),
        sched.total_s(),
        sched.speedup(),
        sched.utilisation() * 100.0
    );

    // Host timing of the whole-training-cost evaluation (the fig6 hot
    // path the perf pass optimises).
    let mut results = Vec::new();
    for net in [Network::lenet5(), Network::cnn_medium()] {
        let name = format!("training_cost({}, 300 steps)", net.name);
        let netc = net.clone();
        results.push(bench(&name, 10, 2_000, || {
            let c = Accelerator::new(AccelKind::Proposed, FloatFormat::FP32, 32_768)
                .training_cost(&netc, 32, 300);
            std::hint::black_box(c);
        }));
    }
    let netc = Network::lenet5();
    results.push(bench("plan + area (lenet5)", 10, 5_000, || {
        let a = Accelerator::new(AccelKind::FloatPim, FloatFormat::FP32, 32_768);
        std::hint::black_box(a.area_m2(&netc, 32));
    }));
    emit("fig6_training", &results);
}
