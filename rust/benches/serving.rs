//! PR 9 serving-tier bench + acceptance gates.
//!
//! Five deterministic virtual-time scenarios (open-loop Poisson
//! arrivals, seed 42, the default `BatchPolicy`) over real warm
//! resident-panel engines — wall-clock entries time the simulation
//! itself (forward compute dominates), `metric:` entries carry the
//! serving SLO numbers in `mean_ns`:
//!
//! * **1.0x healthy** — 10^5 arrivals at the fleet's saturated
//!   capacity: the headline `tools/check_bench_regression.py` gates;
//! * **2.0x healthy** — overload: admission control must reject
//!   deterministically and keep the admitted p99 bounded;
//! * **0.5x healthy** — light load: coalescing trades partial batches
//!   for bounded latency, nothing is lost;
//! * **1.0x-of-healthy, one chip dead** — `chip_dead=1,seed=9`: the
//!   survivor serves at reduced capacity, ABFT checksum waves priced
//!   into every request's latency;
//! * **1.0x sparse** — the PR 10 block-sparse model (`block=4,
//!   ratio=0.75`, pruned blocks pinned at +0.0): the fleet's capacity
//!   rises with the skipped weight panels, so it serves **more krps
//!   than the dense healthy scenario under the same analytic p99
//!   gate** (in-binary assert).
//!
//! In-binary acceptance gates: request conservation in every scenario,
//! zero unrecovered faults, admitted p99 within the analytic
//! `BatchPolicy::p99_bound_s` cap (env `SERVING_P99_BOUND_FACTOR`
//! relaxes on noisy runners), and a steady-state zero-allocation audit
//! (a warmed run replayed end-to-end touches the heap zero times; env
//! `SERVING_ALLOC_TOLERANCE`).  The regression script holds the p99 /
//! shed-rate metrics under ceiling gates and the two zero counters
//! under exact gates.
//!
//! Run: `cargo bench --bench serving` (add `-- --json` for
//! `BENCH_serving.json`).

use std::sync::Arc;

use mram_pim::arch::{NetworkParams, SparsityConfig};
use mram_pim::bench::{bench, emit, heap_allocations, BenchResult, CountingAllocator};
use mram_pim::data::Dataset;
use mram_pim::fpu::FpCostModel;
use mram_pim::model::Network;
use mram_pim::runtime::FUNCTIONAL_LANES;
use mram_pim::serve::{open_loop_arrivals, BatchPolicy, InferBackend, ServeReport, ServeSim};
use mram_pim::sim::{FaultConfig, FaultSession};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// A scalar-metric pseudo-entry (SLO value in `mean_ns`): keeps the
/// serving trajectory in the same JSON sidecar the wall-clock entries
/// use, so the regression gate can watch it.
fn metric(name: &str, v: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: v,
        p50_ns: v,
        p99_ns: v,
        min_ns: v,
    }
}

fn make_backend(session: Option<Arc<FaultSession>>, sparse: bool) -> InferBackend {
    let net = Network::lenet5();
    let mut params = NetworkParams::init(&net, 3);
    if sparse {
        // PR 10 block-sparse model: pruned blocks pinned at +0.0, their
        // forward waves skipped and the skip priced into svc latency.
        SparsityConfig {
            block_rows: 4,
            ratio: 0.75,
        }
        .ensure(&mut params);
    }
    InferBackend::new(
        net,
        params,
        FpCostModel::proposed_fp32(),
        FUNCTIONAL_LANES,
        4,
        2,
        session,
    )
    .expect("serve backend")
}

fn pool() -> Vec<f32> {
    Dataset::synthetic(256, 7).full_batch(256).images
}

fn main() {
    let policy = BatchPolicy::default();
    let bound_factor = env_f64("SERVING_P99_BOUND_FACTOR", 1.0);
    let mut results: Vec<BenchResult> = Vec::new();
    let mut reports: Vec<ServeReport> = Vec::new();
    let mut total_unrecovered = 0u64;

    let scenarios: [(&str, usize, f64, bool, bool); 5] = [
        ("serving: 100000 open-loop arrivals @ 1.0x offered load (chips 2, healthy)",
         100_000, 1.0, false, false),
        ("serving: 20000 open-loop arrivals @ 2.0x offered load (chips 2, healthy)",
         20_000, 2.0, false, false),
        ("serving: 20000 open-loop arrivals @ 0.5x offered load (chips 2, healthy)",
         20_000, 0.5, false, false),
        ("serving: 20000 open-loop arrivals @ 1.0x-of-healthy load (chips 2, one dead)",
         20_000, 1.0, true, false),
        ("serving: 20000 open-loop arrivals @ 1.0x offered load \
          (chips 2, sparse block=4 ratio=0.75)",
         20_000, 1.0, false, true),
    ];

    let mut dense_capacity = 0.0f64;
    for (name, n, mult, dead, sparse) in scenarios {
        let session = if dead {
            Some(Arc::new(FaultSession::new(
                FaultConfig::parse("chip_dead=1,seed=9").expect("fault spec"),
            )))
        } else {
            None
        };
        let mut sim = ServeSim::new(make_backend(session.clone(), sparse), policy, pool(), n)
            .expect("serve sim");
        let cap = sim.capacity_rps();
        if !dead && !sparse && dense_capacity == 0.0 {
            dense_capacity = cap;
        }
        sim.warm().expect("warm");
        let arrivals = open_loop_arrivals(n, mult * cap, 42);
        let mut report: Option<ServeReport> = None;
        let r = bench(name, 0, 1, || {
            report = Some(sim.run(&arrivals).expect("serve run"));
        });
        let report = report.expect("one timed run");
        let st = report.stats;

        // ---- acceptance gates, per scenario ----
        assert!(st.conservation_holds(), "{name}: request conservation broke: {st:?}");
        assert_eq!(st.submitted, n as u64, "{name}: every arrival must be accounted");
        assert!(
            st.batched_samples <= st.batches * policy.max_batch as u64,
            "{name}: a batch exceeded max_batch"
        );
        assert_eq!(st.failed, 0, "{name}: no batch may fail on unrecovered faults");
        let bound = policy.p99_bound_s(sim.backend().svc_latency(policy.max_batch))
            * bound_factor;
        assert!(
            report.p99_s <= bound,
            "{name}: admitted p99 {:.3} ms over the analytic bound {:.3} ms",
            report.p99_s * 1e3,
            bound * 1e3
        );
        if let Some(s) = &session {
            total_unrecovered += s.report().unrecovered;
            assert!(
                st.fault_latency_s > 0.0,
                "{name}: ABFT pricing must reach per-request latency"
            );
            assert_eq!(sim.live_chips(), 1, "{name}: chip_dead=1 leaves one survivor");
        }
        if sparse {
            // The block-sparse fleet serves *more* requests per second
            // under the same analytic p99 gate: skipped weight panels
            // shorten every forward wave train.
            assert!(
                st.live_block_ratio < 1.0 && st.skipped_waves > 0,
                "{name}: sparse backend skipped nothing: {st:?}"
            );
            assert!(
                cap > dense_capacity,
                "{name}: sparse capacity {cap:.0} rps must exceed dense \
                 {dense_capacity:.0} rps"
            );
            assert!(
                report.throughput_rps > reports[0].throughput_rps,
                "{name}: sparse throughput {:.1} krps must beat the dense healthy \
                 scenario's {:.1} krps at the same p99 gate",
                report.throughput_rps / 1e3,
                reports[0].throughput_rps / 1e3,
            );
        } else {
            assert_eq!(st.skipped_waves, 0, "{name}: dense panels must skip nothing");
            assert_eq!(st.live_block_ratio, 1.0);
        }
        println!(
            "{name}\n  admitted {} / rejected {} / shed {} / completed {}  \
             batches {} (mean {:.1})  thr {:.1} krps  p50 {:.3} ms  p99 {:.3} ms",
            st.admitted,
            st.rejected,
            st.shed,
            st.completed,
            st.batches,
            st.batched_samples as f64 / st.batches.max(1) as f64,
            report.throughput_rps / 1e3,
            report.p50_s * 1e3,
            report.p99_s * 1e3,
        );
        results.push(r);
        reports.push(report);
    }

    // ---- steady-state allocation audit: a warmed (unarmed) scenario
    //      replayed end-to-end must not touch the heap — armed runs
    //      advance hook epochs and legitimately diverge, so the audit
    //      scenario runs clean ----
    let mut audit =
        ServeSim::new(make_backend(None, false), policy, pool(), 4000).expect("audit sim");
    let audit_arrivals = open_loop_arrivals(4000, 1.2 * audit.capacity_rps(), 42);
    audit.warm().expect("audit warm");
    audit.run(&audit_arrivals).expect("audit settle run");
    let allocs0 = heap_allocations();
    let audit_report = audit.run(&audit_arrivals).expect("audit run");
    let dispatch_allocs = heap_allocations() - allocs0;
    assert!(audit_report.stats.conservation_holds());
    println!("steady-state audit (warmed serving run, 4000 arrivals): {dispatch_allocs} allocs");

    let (r1, r2, rd) = (&reports[0], &reports[1], &reports[3]);
    results.push(metric(
        "metric: serving throughput krps @1.0x healthy",
        r1.throughput_rps / 1e3,
    ));
    results.push(metric("metric: serving p50 ms @1.0x healthy", r1.p50_s * 1e3));
    results.push(metric("metric: serving p99 ms @1.0x healthy", r1.p99_s * 1e3));
    results.push(metric("metric: serving p99 ms @2.0x healthy", r2.p99_s * 1e3));
    results.push(metric(
        "metric: serving shed+reject pct @2.0x healthy",
        100.0 * (r2.stats.shed + r2.stats.rejected) as f64 / r2.stats.submitted as f64,
    ));
    results.push(metric("metric: serving p99 ms @1.0x one-dead", rd.p99_s * 1e3));
    results.push(metric(
        "metric: serving completed pct @1.0x one-dead",
        100.0 * rd.stats.completed as f64 / rd.stats.submitted as f64,
    ));
    let rsp = &reports[4];
    results.push(metric(
        "metric: serving throughput krps @1.0x sparse-0.75",
        rsp.throughput_rps / 1e3,
    ));
    results.push(metric(
        "metric: serving p99 ms @1.0x sparse-0.75",
        rsp.p99_s * 1e3,
    ));
    results.push(metric(
        "metric: serving live weight pct @1.0x sparse-0.75",
        rsp.stats.live_block_ratio * 100.0,
    ));
    results.push(metric(
        "metric: serving unrecovered faults",
        total_unrecovered as f64,
    ));
    results.push(metric(
        "metric: serving steady-state dispatch allocs",
        dispatch_allocs as f64,
    ));
    emit("serving", &results);

    // ---- final acceptance gates ----
    assert_eq!(total_unrecovered, 0, "acceptance: ABFT must recover every served batch");
    assert!(
        reports[1].stats.rejected > 0,
        "acceptance: 2x overload must reject deterministically"
    );
    assert_eq!(
        reports[2].stats.completed, reports[2].stats.submitted,
        "acceptance: 0.5x load must complete everything"
    );
    let alloc_tolerance = env_f64("SERVING_ALLOC_TOLERANCE", 0.0) as u64;
    assert!(
        dispatch_allocs <= alloc_tolerance,
        "acceptance: a warmed serving run must not touch the heap \
         (measured {dispatch_allocs} allocations, tolerance {alloc_tolerance})"
    );
    println!("serving OK");
}
