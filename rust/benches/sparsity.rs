//! PR 10 block-sparse training bench + acceptance gates.
//!
//! Benches the wide 784-1024-1024-10 MLP (`Network::mlp_wide`) through
//! the pooled resident-panel engine dense and at block-sparsity ratios
//! {0.5, 0.75, 0.9} (block = 4 output rows × one 256-wide K-panel,
//! magnitude-pruned).  Masked blocks are skipped at the wave level, so
//! both the *priced* schedule (waves/latency/energy) and the *host*
//! wall-clock must drop together — sparsity that only discounts the
//! ledger would be a pricing fiction, and sparsity that only helps the
//! host would be unpriced.
//!
//! In-binary acceptance gates:
//!
//! * counted ledger == occupancy-aware analytic `training_work` at
//!   every ratio (MACs, waves, skipped counters — exactly);
//! * at ratio 0.75 the priced wave count drops **≥ 2×** and the host
//!   wall-clock **≥ 1.3×** vs dense (`SPARSITY_MIN_SPEEDUP` overrides
//!   the wall-clock floor for noisy runners);
//! * a ratio-0 mask is **bit-identical** to no mask (loss + updated
//!   parameters; the mismatch count is emitted as an exact-gated
//!   `metric:` with committed baseline 0);
//! * the steady-state masked step performs **zero heap allocations**,
//!   **zero thread spawns** and **zero panel decodes**
//!   (`SPARSITY_ALLOC_TOLERANCE` overrides).
//!
//! `tools/check_bench_regression.py` additionally holds the fresh
//! dense-vs-0.75 wall-clock ratio under `SPARSITY_SLACK_PCT` and the
//! two zero counters under exact gates.
//!
//! Run: `cargo bench --bench sparsity` (add `-- --json` for
//! `BENCH_sparsity.json`).

use mram_pim::arch::pool::worker_launches;
use mram_pim::arch::{
    panel_decodes, NetworkParams, Occupancy, SparsityConfig, TrainEngine, TrainTotals,
};
use mram_pim::bench::{bench, emit, heap_allocations, BenchResult, CountingAllocator};
use mram_pim::data::Dataset;
use mram_pim::fpu::FpCostModel;
use mram_pim::model::Network;
use mram_pim::prop::Rng;
use mram_pim::runtime::FUNCTIONAL_LANES;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn metric(name: &str, v: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: v,
        p50_ns: v,
        p99_ns: v,
        min_ns: v,
    }
}

/// Pruned-and-pinned parameter set for `ratio` (dense when 0 with no
/// mask attached — the true baseline, not a ratio-0 mask).
fn pruned_params(net: &Network, ratio: f64) -> NetworkParams {
    let mut p = NetworkParams::init(net, 7);
    if ratio > 0.0 {
        SparsityConfig {
            block_rows: 4,
            ratio,
        }
        .ensure(&mut p);
    }
    p
}

fn main() {
    let net = Network::mlp_wide();
    let batch = 32usize;
    let mut rng = Rng::new(0x59A5);
    let data = Dataset::synthetic(batch, 0x59A5).full_batch(batch);
    let labels: Vec<i32> = data.labels.clone();
    let images: Vec<f32> = data
        .images
        .iter()
        .map(|&v| v + rng.f32_normal(1) * 1e-6)
        .collect();
    let eng = TrainEngine::new(FpCostModel::proposed_fp32(), FUNCTIONAL_LANES, 4);

    let mut results: Vec<BenchResult> = Vec::new();
    // (ratio, mean_ns, step waves) per entry; ratio 0.0 first.
    let mut measured: Vec<(f64, f64, u64)> = Vec::new();

    for ratio in [0.0f64, 0.5, 0.75, 0.9] {
        let name = if ratio == 0.0 {
            format!("mlp-wide train step batch {batch} (threads 4, pooled, dense)")
        } else {
            format!(
                "mlp-wide train step batch {batch} \
                 (threads 4, pooled, sparse block=4 ratio={ratio})"
            )
        };
        // Steady-state steps on persistent params: the mask (and thus
        // the work) is fixed across iterations; only the weights move.
        let mut p = pruned_params(&net, ratio);
        let warm = eng
            .train_step(&net, &mut p, &images, &labels, batch, 0.05)
            .expect("warm step");
        eng.recycle(warm);
        let r = bench(&name, 0, 4, || {
            let r = eng
                .train_step(&net, &mut p, &images, &labels, batch, 0.05)
                .expect("train step");
            std::hint::black_box(r.loss);
            eng.recycle(r);
        });

        // One verified step: counted ledger == occupancy-aware analytic
        // model, skipped gap accounted exactly.
        let occ = Occupancy::of(&net, &p);
        let step = eng
            .train_step(&net, &mut p, &images, &labels, batch, 0.05)
            .expect("verified step");
        assert!(step.loss.is_finite());
        let mut totals = TrainTotals::default();
        totals.absorb(&step);
        assert!(
            totals.matches_analytic_occ(&net, batch, FUNCTIONAL_LANES as u64, &occ),
            "ratio {ratio}: counted ledger drifted from the occupancy model: {totals:?}"
        );
        let work = occ.training_work(&net, batch);
        println!(
            "ratio {ratio}: {:.1}% weights live, {} waves ({} skipped), \
             {:.1}M MACs ({:.1}M skipped), latency {:.3e} s, energy {:.3e} J, host {:.0} ms",
            occ.live_fraction() * 100.0,
            step.waves,
            step.skipped_waves,
            work.total_macs() as f64 / 1e6,
            step.skipped_macs as f64 / 1e6,
            step.latency_s,
            step.energy_j,
            r.mean_ns / 1e6,
        );
        if ratio == 0.0 {
            assert_eq!(step.skipped_macs, 0, "dense step must skip nothing");
            assert_eq!(step.skipped_waves, 0);
        } else {
            assert!(step.skipped_waves > 0, "ratio {ratio}: no waves skipped");
            assert!(
                step.waves < measured[0].2,
                "ratio {ratio}: priced waves must drop below dense"
            );
            assert!(
                r.mean_ns < measured[0].1,
                "ratio {ratio}: sparse wall-clock must beat dense"
            );
        }
        measured.push((ratio, r.mean_ns, step.waves));
        eng.recycle(step);
        results.push(r);
    }

    // ---- ratio-0 mask ≡ no mask, bit for bit (2 steps) ----
    let mut with_mask = NetworkParams::init(&net, 7);
    SparsityConfig {
        block_rows: 4,
        ratio: 0.0,
    }
    .ensure(&mut with_mask);
    let mut without = NetworkParams::init(&net, 7);
    let mut mismatches = 0u64;
    for _ in 0..2 {
        let rm = eng
            .train_step(&net, &mut with_mask, &images, &labels, batch, 0.05)
            .expect("masked step");
        let rp = eng
            .train_step(&net, &mut without, &images, &labels, batch, 0.05)
            .expect("plain step");
        mismatches += (rm.loss.to_bits() != rp.loss.to_bits()) as u64;
        mismatches += (rm.waves != rp.waves) as u64;
        eng.recycle(rm);
        eng.recycle(rp);
        for (a, b) in with_mask.layers.iter().flatten().zip(without.layers.iter().flatten()) {
            mismatches += a
                .w
                .iter()
                .chain(&a.b)
                .zip(b.w.iter().chain(&b.b))
                .filter(|(x, y)| x.to_bits() != y.to_bits())
                .count() as u64;
        }
    }
    println!("dense-mask vs no-mask bit mismatches over 2 steps: {mismatches}");

    // ---- steady-state audit at ratio 0.75: masked skips must not cost
    //      allocations, spawns or panel re-decodes ----
    let mut p = pruned_params(&net, 0.75);
    for _ in 0..2 {
        let r = eng
            .train_step(&net, &mut p, &images, &labels, batch, 0.05)
            .expect("audit warm");
        eng.recycle(r);
    }
    let spawns0 = worker_launches();
    let allocs0 = heap_allocations();
    let decodes0 = panel_decodes();
    let r = eng
        .train_step(&net, &mut p, &images, &labels, batch, 0.05)
        .expect("audit step");
    eng.recycle(r);
    let audit_allocs = heap_allocations() - allocs0;
    let audit_spawns = worker_launches() - spawns0;
    let audit_decodes = panel_decodes() - decodes0;
    println!(
        "steady-state audit (ratio 0.75): {audit_allocs} allocs / {audit_spawns} spawns / \
         {audit_decodes} panel decodes"
    );

    let (dense_ns, dense_waves) = (measured[0].1, measured[0].2);
    let (r75_ns, r75_waves) = (measured[2].1, measured[2].2);
    let wave_ratio = dense_waves as f64 / r75_waves as f64;
    let speedup = dense_ns / r75_ns;
    println!(
        "dense vs ratio 0.75: priced waves {wave_ratio:.2}x [acceptance: >=2x], \
         host wall {speedup:.2}x [acceptance: >=1.3x]"
    );

    results.push(metric("metric: sparsity priced wave ratio dense/0.75", wave_ratio));
    results.push(metric("metric: sparsity wall speedup dense/0.75", speedup));
    results.push(metric(
        "metric: sparsity dense-mask bit mismatches",
        mismatches as f64,
    ));
    results.push(metric(
        "metric: sparsity steady-state allocs (ratio 0.75)",
        audit_allocs as f64,
    ));
    emit("sparsity", &results);

    // ---- acceptance gates ----
    assert_eq!(
        mismatches, 0,
        "acceptance: a ratio-0 mask must be bit-identical to dense training"
    );
    assert!(
        wave_ratio >= 2.0,
        "acceptance: ratio 0.75 must cut priced waves >= 2x (measured {wave_ratio:.2}x)"
    );
    let min_speedup = env_f64("SPARSITY_MIN_SPEEDUP", 1.3);
    assert!(
        speedup >= min_speedup,
        "acceptance: ratio 0.75 must cut host wall-clock >= {min_speedup}x \
         (measured {speedup:.2}x)"
    );
    let alloc_tolerance = env_f64("SPARSITY_ALLOC_TOLERANCE", 0.0) as u64;
    assert!(
        audit_allocs <= alloc_tolerance,
        "acceptance: steady-state masked train step must not touch the heap \
         (measured {audit_allocs} allocations, tolerance {alloc_tolerance})"
    );
    assert_eq!(audit_spawns, 0, "acceptance: masked step must not spawn threads");
    assert_eq!(
        audit_decodes, 0,
        "acceptance: masked step must not re-decode weight panels"
    );
    println!("sparsity OK");
}
