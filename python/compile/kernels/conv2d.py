"""L1: valid 2-D convolution as im2col patches x Pallas matmul.

The patch extraction (``conv_general_dilated_patches``) is pure data movement
and stays in jnp where XLA fuses it; every FLOP of the convolution goes
through :func:`kernels.matmul.matmul`, i.e. the Pallas kernel, in both the
forward and backward pass (via the kernel's custom VJP).

Layout convention: NCHW activations, OIHW weights -- matching the paper's
LeNet description and the rust-side `model::Layer` shapes.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .matmul import matmul


def im2col(x, kh: int, kw: int):
    """x: f[B, C, H, W] -> patches f[B*OH*OW, C*kh*kw] (valid, stride 1).

    Column ordering is (C, kh, kw) fastest-last, matching a reshape of an
    OIHW weight tensor to [O, C*kh*kw].
    """
    b, c, h, w = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    # [B, C*kh*kw, OH, OW]; feature dim ordered (C, kh, kw).
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    # -> [B, OH, OW, C*kh*kw] -> [B*OH*OW, C*kh*kw]
    patches = jnp.moveaxis(patches, 1, -1)
    return patches.reshape(b * oh * ow, c * kh * kw), (b, oh, ow)


def conv2d(x, w, b=None):
    """Valid stride-1 convolution.

    x: f[B, C, H, W]; w: f[O, C, KH, KW]; b: f[O] or None.
    Returns f[B, O, OH, OW].
    """
    o, c, kh, kw = w.shape
    cols, (batch, oh, ow) = im2col(x, kh, kw)          # [B*OH*OW, C*kh*kw]
    wmat = w.reshape(o, c * kh * kw).T                 # [C*kh*kw, O]
    out = matmul(cols, wmat)                           # [B*OH*OW, O]
    out = out.reshape(batch, oh, ow, o)
    out = jnp.moveaxis(out, -1, 1)                     # [B, O, OH, OW]
    if b is not None:
        out = out + b[None, :, None, None]
    return out


def avg_pool2(x):
    """2x2 average pool, stride 2. x: f[B, C, H, W] with even H, W."""
    b, c, h, w = x.shape
    x = x.reshape(b, c, h // 2, 2, w // 2, 2)
    return x.mean(axis=(3, 5))
