"""L1 Pallas kernel: bit-level emulation of the paper's PIM floating-point
datapath (section 3.3).

The accelerator computes fp32 arithmetic *digitally inside the memory array*:

* **multiply** -- the mantissa product is formed by the paper's
  shift-and-add procedure (Fig. 4b): the 24-bit multiplicand is ANDed with
  one multiplier bit at a time, shifted, and accumulated into a two-limb
  carry-propagate result held in two cache columns;
* **add** -- exponents are aligned with the CAM-style "search" (Fig. 4a)
  which shifts the smaller mantissa by the exponent difference in one go
  (the O(Nm) scheme), then the mantissas are added with the 4-step full
  adder and renormalised.

This kernel reproduces those procedures bit-for-bit on uint32 lanes: one
subarray **row** in the paper maps to one vector **lane** here, so the
row-parallelism the memory array provides is expressed as lane-parallelism
in the TPU VPU (see DESIGN.md `Hardware-Adaptation`).  The point is
*certification*, not speed: the procedures must produce IEEE-754
round-to-nearest-even results (with flush-to-zero for subnormals, the
digital-PIM convention) so that training in the simulator is numerically
identical to training on the host.

The rust simulator (`rust/src/fpu/`) implements the same procedures over
simulated memory cells; `rust/tests/runtime_artifacts.rs` checks rust, this
kernel (via the AOT artifact) and host IEEE agree on the same operands.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

U32 = jnp.uint32
I32 = jnp.int32

# Plain python ints: jnp array constants at module scope would be captured
# by the pallas kernel trace ("captures constants" error); literals are not.
_QNAN = 0x7FC00000
_EXP_MASK = 0xFF
_FRAC_MASK = 0x7FFFFF
_IMPLIED = 0x800000

MANTISSA_BITS = 24  # 23 stored + 1 implied
LANES = 1024  # one 1024-wide subarray row per grid step


def _u(x):
    return jnp.asarray(x, U32)


def _fields(bits):
    """Unpack sign / biased exponent / fraction from raw fp32 bits."""
    sign = bits >> 31
    exp = (bits >> 23) & _EXP_MASK
    frac = bits & _FRAC_MASK
    return sign, exp, frac


def _msb_pos(x):
    """Index of the most significant set bit (x assumed > 0), vectorised.

    The PIM array finds this with a parallel search over bit columns; here
    it is the classic 5-step binary reduction.
    """
    p = jnp.zeros_like(x)
    for sh in (16, 8, 4, 2, 1):
        big = x >= _u(1 << sh)
        x = jnp.where(big, x >> sh, x)
        p = jnp.where(big, p + _u(sh), p)
    return p


def mul_bits(abits, bbits):
    """fp32 multiply on raw bits via the paper's shift-and-add procedure.

    Semantics: IEEE-754 round-to-nearest-even with flush-to-zero (FTZ) for
    subnormal inputs and outputs; NaN results are canonical 0x7FC00000.
    """
    sa, ea, fa = _fields(abits)
    sb, eb, fb = _fields(bbits)

    a_nan = (ea == 255) & (fa != 0)
    b_nan = (eb == 255) & (fb != 0)
    a_inf = (ea == 255) & (fa == 0)
    b_inf = (eb == 255) & (fb == 0)
    a_zero = ea == 0  # FTZ: exponent 0 => value treated as (signed) zero
    b_zero = eb == 0

    sign = sa ^ sb
    ma = jnp.where(a_zero, _u(0), fa | _IMPLIED)  # 24-bit significand
    mb = jnp.where(b_zero, _u(0), fb | _IMPLIED)

    # ---- mantissa product: shift-and-add into two 32-bit limbs ----------
    # The paper stores the running partial sum in two cache columns that
    # swap roles each step; two u32 limbs (lo, hi) model the 48-bit value.
    lo = jnp.zeros_like(ma)
    hi = jnp.zeros_like(ma)
    for i in range(MANTISSA_BITS):
        bit = (mb >> i) & _u(1)
        addend = ma * bit                     # AND row: ma or 0
        add_lo = addend << i                  # low 32 of (addend << i)
        # (addend >> (32 - i)) written as two shifts so i = 0 stays legal.
        add_hi = (addend >> (31 - i)) >> 1 if i > 0 else jnp.zeros_like(ma)
        new_lo = lo + add_lo
        carry = jnp.where(new_lo < lo, _u(1), _u(0))
        hi = hi + add_hi + carry
        lo = new_lo

    # ---- normalise + round-to-nearest-even ------------------------------
    # Product of two [2^23, 2^24) significands lies in [2^46, 2^48).
    top_set = (hi >> 15) & _u(1)              # bit 47 of the product
    # Drop `s` low bits so 24 significand bits remain (implied bit at 23).
    # s = 24 when bit47 set (product in [2,4)), else 23.
    m24_s24 = ((lo >> 24) | (hi << 8)) & _u(0xFFFFFF)
    m24_s23 = ((lo >> 23) | (hi << 9)) & _u(0xFFFFFF)
    mant = jnp.where(top_set == 1, m24_s24, m24_s23)
    guard = jnp.where(top_set == 1, (lo >> 23) & _u(1), (lo >> 22) & _u(1))
    sticky = jnp.where(
        top_set == 1, (lo & _u(0x7FFFFF)) != 0, (lo & _u(0x3FFFFF)) != 0
    )
    round_up = (guard == 1) & (sticky | ((mant & _u(1)) == 1))
    mant = mant + jnp.where(round_up, _u(1), _u(0))
    mant_ovf = mant == _u(1 << 24)
    mant = jnp.where(mant_ovf, mant >> 1, mant)

    e0 = ea.astype(I32) + eb.astype(I32) - 127 + top_set.astype(I32)
    e = e0 + mant_ovf.astype(I32)

    normal = (sign << 31) | (e.astype(U32) << 23) | (mant & _FRAC_MASK)
    overflow = e >= 255
    underflow = e <= 0  # below the normal range
    # Subnormal-boundary case: IEEE gradual-underflow rounding sends any
    # value >= min_normal - 2^-150 up to min_normal (tie-to-even lands on
    # the even mantissa).  That happens exactly when the pre-round
    # significand at e0 == 0 has all 24 bits set; everything else in the
    # subnormal range flushes to zero (FTZ).
    boundary = (e0 == 0) & (
        jnp.where(top_set == 1, m24_s24, m24_s23) == _u(0xFFFFFF)
    )
    min_normal = (sign << 31) | _u(0x00800000)

    result = jnp.where(underflow, jnp.where(boundary, min_normal, sign << 31), normal)
    result = jnp.where(overflow, (sign << 31) | _u(0x7F800000), result)
    result = jnp.where(a_zero | b_zero, sign << 31, result)
    result = jnp.where(a_inf | b_inf, (sign << 31) | _u(0x7F800000), result)
    is_nan = a_nan | b_nan | (a_inf & b_zero) | (b_inf & a_zero)
    result = jnp.where(is_nan, _QNAN, result)
    return result


def add_bits(abits, bbits):
    """fp32 add on raw bits via search-aligned mantissa addition.

    Exponent alignment happens in ONE shift of `d` bits (the proposed
    O(Nm) scheme -- the 1T-1R cell lets whole groups of rows shift by the
    amount found by the CAM search), then a carry-propagate mantissa
    add/sub and renormalisation.  IEEE RNE + FTZ semantics as `mul_bits`.
    """
    sa, ea, fa = _fields(abits)
    sb, eb, fb = _fields(bbits)

    a_nan = (ea == 255) & (fa != 0)
    b_nan = (eb == 255) & (fb != 0)
    a_inf = (ea == 255) & (fa == 0)
    b_inf = (eb == 255) & (fb == 0)
    a_zero = ea == 0  # FTZ
    b_zero = eb == 0

    # Order by magnitude: |x| >= |y|.  Magnitude order == integer order of
    # the low 31 bits for (FTZ-)normal values.
    amag = abits & _u(0x7FFFFFFF)
    bmag = bbits & _u(0x7FFFFFFF)
    a_big = amag >= bmag
    sx = jnp.where(a_big, sa, sb)
    ex = jnp.where(a_big, ea, eb)
    fx = jnp.where(a_big, fa, fb)
    sy = jnp.where(a_big, sb, sa)
    ey = jnp.where(a_big, eb, ea)
    fy = jnp.where(a_big, fb, fa)

    mx = (fx | _IMPLIED) << 3  # 27 bits: significand + G,R,S space
    my = (fy | _IMPLIED) << 3

    # ---- exponent alignment: single d-bit shift (the "search" result) ---
    d = (ex - ey).astype(U32)
    d_c = jnp.minimum(d, _u(27))
    lost = my & ((_u(1) << d_c) - _u(1))
    my_al = (my >> d_c) | jnp.where(lost != 0, _u(1), _u(0))  # fold sticky

    subtract = sx != sy
    total = jnp.where(subtract, mx - my_al, mx + my_al)  # <= 28 bits

    # ---- renormalise ------------------------------------------------------
    is_cancel = total == 0
    safe_total = jnp.where(is_cancel, _u(1), total)
    p = _msb_pos(safe_total)  # target implied-bit position is 26

    shift_r = p == _u(27)  # carry out: shift right 1, keep sticky
    total_r = (safe_total >> 1) | (safe_total & _u(1))
    shl = jnp.where(p < _u(26), _u(26) - p, _u(0))
    total_n = jnp.where(shift_r, total_r, safe_total << shl)

    kept = total_n >> 3  # 24-bit significand
    kept_preround = kept
    rb = (total_n >> 2) & _u(1)
    st = (total_n & _u(3)) != 0
    round_up = (rb == 1) & (st | ((kept & _u(1)) == 1))
    kept = kept + jnp.where(round_up, _u(1), _u(0))
    kept_ovf = kept == _u(1 << 24)
    kept = jnp.where(kept_ovf, kept >> 1, kept)

    e0 = ex.astype(I32) + jnp.where(shift_r, 1, 0) - shl.astype(I32)
    e = e0 + kept_ovf.astype(I32)

    normal = (sx << 31) | (e.astype(U32) << 23) | (kept & _FRAC_MASK)
    # Same subnormal-boundary handling as mul_bits: all-ones pre-round
    # significand at e0 == 0 rounds up to min_normal under IEEE gradual
    # underflow; everything else below the normal range flushes (FTZ).
    boundary = (e0 == 0) & (kept_preround == _u(0xFFFFFF))
    min_normal = (sx << 31) | _u(0x00800000)
    # Inexact subnormal results flush to *signed* zero; only exact
    # cancellation yields +0 (the RNE rule).
    underflowed = jnp.where(boundary, min_normal, sx << 31)
    result = jnp.where(is_cancel, _u(0), jnp.where(e <= 0, underflowed, normal))
    result = jnp.where(e >= 255, (sx << 31) | _u(0x7F800000), result)

    # ---- specials ----------------------------------------------------------
    # zeros: x + (+-0) = x;  (+-0) + (+-0): +0 under RNE unless both -0.
    both_zero_sign = (sa & sb) << 31
    result = jnp.where(a_zero & b_zero, both_zero_sign, result)
    result = jnp.where(a_zero & ~b_zero, bbits, result)
    result = jnp.where(b_zero & ~a_zero, abits, result)
    # infinities
    result = jnp.where(a_inf, abits, result)
    result = jnp.where(b_inf, bbits, result)
    is_nan = a_nan | b_nan | (a_inf & b_inf & (sa != sb))
    result = jnp.where(is_nan, _QNAN, result)
    return result


def mac_bits(abits, bbits, cbits):
    """Non-fused PIM MAC: round(round(a*b) + c) -- two array passes."""
    return add_bits(mul_bits(abits, bbits), cbits)


# --------------------------------------------------------------------------
# Pallas wrappers: one grid step processes one LANES-wide subarray row.
# --------------------------------------------------------------------------


def _wrap_binary(bit_fn):
    def kernel(a_ref, b_ref, o_ref):
        o_ref[...] = bit_fn(a_ref[...], b_ref[...])

    def call(abits, bbits):
        (n,) = abits.shape
        assert n % LANES == 0, f"operand length {n} not a multiple of {LANES}"
        return pl.pallas_call(
            kernel,
            grid=(n // LANES,),
            in_specs=[
                pl.BlockSpec((LANES,), lambda i: (i,)),
                pl.BlockSpec((LANES,), lambda i: (i,)),
            ],
            out_specs=pl.BlockSpec((LANES,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((n,), U32),
            interpret=True,
        )(abits, bbits)

    return call


pim_mul_u32 = _wrap_binary(mul_bits)
pim_add_u32 = _wrap_binary(add_bits)


def pim_mul_f32(a, b):
    """fp32 in/out wrapper: bitcast -> PIM multiply kernel -> bitcast."""
    bits = pim_mul_u32(
        jax.lax.bitcast_convert_type(a, U32), jax.lax.bitcast_convert_type(b, U32)
    )
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def pim_add_f32(a, b):
    """fp32 in/out wrapper: bitcast -> PIM add kernel -> bitcast."""
    bits = pim_add_u32(
        jax.lax.bitcast_convert_type(a, U32), jax.lax.bitcast_convert_type(b, U32)
    )
    return jax.lax.bitcast_convert_type(bits, jnp.float32)
