"""L1 Pallas kernel: tiled matrix multiply with a custom VJP.

This is the single dense-compute primitive the whole LeNet training graph is
built on (convolutions are lowered to im2col patches x weights, FC layers use
it directly).  The kernel is written for TPU-style tiling -- (block_m x K) LHS
block and (K x block_n) RHS block streamed into VMEM, accumulated in fp32 on
the MXU -- but is lowered here with ``interpret=True`` so the emitted HLO runs
on any PJRT backend (see DESIGN.md `Hardware-Adaptation`).

The custom VJP routes both backward matmuls through the same Pallas kernel so
the *entire* training step, forward and backward, exercises the L1 kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default block sizes: multiples of the TPU (8, 128) fp32 tile; a
# (128 x K) + (K x 128) + (128 x 128) working set stays well under VMEM for
# every K used by LeNet (K <= 1152).
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (block_m x block_n) output tile: full-K dot in fp32."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _matmul_impl(a, b, *, block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N):
    """Pad-to-tile, run the Pallas grid, slice back to the true shape."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    out_dtype = jnp.result_type(a.dtype, b.dtype)

    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 128))
    pm, pn = _ceil_to(m, bm), _ceil_to(n, bn)
    pa = jnp.pad(a, ((0, pm - m), (0, 0))) if pm != m else a
    pb = jnp.pad(b, ((0, 0), (0, pn - n))) if pn != n else b

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(pm // bm, pn // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), out_dtype),
        interpret=True,
    )(pa, pb)
    return out[:m, :n]


@jax.custom_vjp
def matmul(a, b):
    """``a @ b`` through the Pallas kernel, differentiable.

    a: f[M, K], b: f[K, N] -> f[M, N] (fp32 accumulation).
    """
    return _matmul_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # dA = g @ B^T, dB = A^T @ g -- both through the same Pallas kernel.
    da = _matmul_impl(g, b.T).astype(a.dtype)
    db = _matmul_impl(a.T, g).astype(b.dtype)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul_jit(a, b, block_m=DEFAULT_BLOCK_M, block_n=DEFAULT_BLOCK_N):
    """Jitted non-VJP entry point used by the shape/dtype sweep tests."""
    return _matmul_impl(a, b, block_m=block_m, block_n=block_n)
