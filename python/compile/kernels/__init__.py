"""L1 Pallas kernels (build-time only; lowered into the AOT artifacts)."""

from . import conv2d, matmul, pim_mac, ref  # noqa: F401
