"""Pure-jnp / numpy oracles for every L1 kernel.

These are the correctness references the pytest + hypothesis suites compare
the Pallas kernels against.  Nothing here is ever lowered into an artifact.
"""

import jax.numpy as jnp
import numpy as np
from jax import lax


def matmul_ref(a, b):
    """Oracle for kernels.matmul.matmul."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(
        jnp.result_type(a.dtype, b.dtype)
    )


def conv2d_ref(x, w, b=None):
    """Oracle for kernels.conv2d.conv2d: XLA's own convolution."""
    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        out = out + b[None, :, None, None]
    return out


def avg_pool2_ref(x):
    """Oracle for kernels.conv2d.avg_pool2."""
    return lax.reduce_window(
        x, 0.0, lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    ) / 4.0


def _ftz32(x):
    """Flush subnormals to (sign-preserving) zero, the PIM convention."""
    x = np.asarray(x, np.float32)
    bits = x.view(np.uint32)
    sub = (bits & 0x7F800000) == 0
    out = np.where(sub, (bits & 0x80000000).astype(np.uint32), bits)
    return out.view(np.float32)


def pim_mul_ref(a, b):
    """Oracle for the PIM multiply: host IEEE multiply under FTZ."""
    return _ftz32(_ftz32(a) * _ftz32(b))


def pim_add_ref(a, b):
    """Oracle for the PIM add: host IEEE add under FTZ."""
    return _ftz32(_ftz32(a) + _ftz32(b))
