"""L2: the LeNet-5-style training graph in JAX, built on the L1 kernels.

This is the DNN the paper trains (section 4.1: "LeNet-type DNN model with
21,690 parameters of 32-bit floating point precision", MNIST, fp32).  The
topology below is the classic valid-conv LeNet pipeline

    conv 5x5 1->6  - relu - avgpool2
    conv 5x5 6->12 - relu - avgpool2
    fc 192->97     - relu
    fc 97->10      - log-softmax

which lands at 21,669 parameters, within 21 of the paper's quoted count
(the paper does not publish the exact layer table; DESIGN.md records the
delta).  Every dense FLOP -- conv forward/backward and both FC layers --
flows through the Pallas matmul kernel via `kernels.conv2d` /
`kernels.matmul`, so the lowered HLO artifact contains exactly the compute
the rust-side PIM cost simulator prices.

Only jitted *pure functions* live here; `aot.py` lowers them once to HLO
text and the rust runtime executes them.  Python never runs at request
time.
"""

import jax
import jax.numpy as jnp

from .kernels.conv2d import avg_pool2, conv2d
from .kernels.matmul import matmul

# Layer table (kept in sync with rust/src/model/lenet.rs).
CONV1 = dict(out=6, inp=1, kh=5, kw=5)
CONV2 = dict(out=12, inp=6, kh=5, kw=5)
FC1 = dict(inp=192, out=97)
FC2 = dict(inp=97, out=10)
NUM_CLASSES = 10
IMAGE_HW = 28

TRAIN_BATCH = 32
EVAL_BATCH = 256

PARAM_NAMES = ("w1", "b1", "w2", "b2", "w3", "b3", "w4", "b4")


def param_shapes():
    """Shapes of the 8 parameter tensors, in artifact argument order."""
    return (
        (CONV1["out"], CONV1["inp"], CONV1["kh"], CONV1["kw"]),
        (CONV1["out"],),
        (CONV2["out"], CONV2["inp"], CONV2["kh"], CONV2["kw"]),
        (CONV2["out"],),
        (FC1["inp"], FC1["out"]),
        (FC1["out"],),
        (FC2["inp"], FC2["out"]),
        (FC2["out"],),
    )


def param_count():
    return sum(int(jnp.prod(jnp.array(s))) for s in param_shapes())


def init_params(seed=0):
    """He-uniform initialisation, deterministic in `seed`."""
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in param_shapes():
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[1:] if len(shape) == 4 else shape[:1]:
                fan_in *= d
            bound = jnp.sqrt(6.0 / fan_in)
            params.append(
                jax.random.uniform(
                    sub, shape, jnp.float32, minval=-bound, maxval=bound
                )
            )
    return tuple(params)


def forward(params, x):
    """Logits for a batch. x: f32[B, 1, 28, 28] -> f32[B, 10]."""
    w1, b1, w2, b2, w3, b3, w4, b4 = params
    h = jax.nn.relu(conv2d(x, w1, b1))     # [B, 6, 24, 24]
    h = avg_pool2(h)                       # [B, 6, 12, 12]
    h = jax.nn.relu(conv2d(h, w2, b2))     # [B, 12, 8, 8]
    h = avg_pool2(h)                       # [B, 12, 4, 4]
    h = h.reshape(h.shape[0], -1)          # [B, 192]
    h = jax.nn.relu(matmul(h, w3) + b3)    # [B, 97]
    return matmul(h, w4) + b4              # [B, 10]


def loss_fn(params, x, y):
    """Mean cross-entropy. y: i32[B] class ids."""
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def train_step(*args):
    """(p0..p7, x, y, lr) -> (p0'..p7', loss). One SGD step."""
    params, (x, y, lr) = args[:8], args[8:]
    loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
    new_params = tuple(p - lr * g for p, g in zip(params, grads))
    return new_params + (loss,)


def eval_step(*args):
    """(p0..p7, x, y) -> (loss, correct). correct is an f32 count."""
    params, (x, y) = args[:8], args[8:]
    logits = forward(params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    y = y.astype(jnp.int32)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return jnp.mean(nll), correct


def init_step(seed):
    """(seed:i32[]) -> (p0..p7). Deterministic parameter initialisation."""
    key = jax.random.PRNGKey(seed)
    params = []
    for shape in param_shapes():
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[1:] if len(shape) == 4 else shape[:1]:
                fan_in *= d
            bound = jnp.sqrt(6.0 / fan_in)
            params.append(
                jax.random.uniform(
                    sub, shape, jnp.float32, minval=-bound, maxval=bound
                )
            )
    return tuple(params)
