"""AOT pipeline: lower every L2/L1 entry point once to HLO *text* artifacts.

Run as ``python -m compile.aot --out-dir ../artifacts`` (wired as
``make artifacts``).  The rust runtime (`rust/src/runtime/`) loads these
with ``HloModuleProto::from_text_file`` and executes them on the PJRT CPU
client; python is never on the request path.

HLO text -- NOT ``lowered.compile()`` / proto ``.serialize()`` -- is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import pim_mac


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def artifact_specs():
    """name -> (fn, example_args, doc). Shapes are the runtime contract."""
    pshapes = model.param_shapes()
    params = [_f32(*s) for s in pshapes]
    tb, eb, hw = model.TRAIN_BATCH, model.EVAL_BATCH, model.IMAGE_HW
    n = pim_mac.LANES

    def train_tuple(*a):
        return model.train_step(*a)

    def eval_tuple(*a):
        return model.eval_step(*a)

    def init_tuple(seed):
        return model.init_step(seed)

    def pim_mul(a, b):
        return (pim_mac.pim_mul_f32(a, b),)

    def pim_add(a, b):
        return (pim_mac.pim_add_f32(a, b),)

    return {
        "lenet_train_step": (
            train_tuple,
            params + [_f32(tb, 1, hw, hw), _i32(tb), _f32()],
            f"(p0..p7, x f32[{tb},1,{hw},{hw}], y i32[{tb}], lr f32[]) -> (p0'..p7', loss)",
        ),
        "lenet_eval": (
            eval_tuple,
            params + [_f32(eb, 1, hw, hw), _i32(eb)],
            f"(p0..p7, x f32[{eb},1,{hw},{hw}], y i32[{eb}]) -> (loss, correct)",
        ),
        "lenet_init": (
            init_tuple,
            [_i32()],
            "(seed i32[]) -> (p0..p7)",
        ),
        "pim_fp32_mul": (
            pim_mul,
            [_f32(n), _f32(n)],
            f"(a f32[{n}], b f32[{n}]) -> (a*b via bit-level PIM shift-and-add,)",
        ),
        "pim_fp32_add": (
            pim_add,
            [_f32(n), _f32(n)],
            f"(a f32[{n}], b f32[{n}]) -> (a+b via bit-level PIM search-align add,)",
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    for name, (fn, example_args, doc) in artifact_specs().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(f"{name}.hlo.txt\t{doc}")
        print(f"wrote {path} ({len(text)} chars)")

    if not args.only:
        manifest.append(f"# param_count={model.param_count()}")
        manifest.append(
            f"# train_batch={model.TRAIN_BATCH} eval_batch={model.EVAL_BATCH}"
        )
        with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest) + "\n")
        print(f"wrote {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
