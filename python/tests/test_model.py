"""L2 model: shapes, parameter count, gradients, training progress."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


def _batch(rng, n):
    x = rng.standard_normal((n, 1, 28, 28)).astype(np.float32)
    y = rng.integers(0, 10, n).astype(np.int32)
    return x, y


def test_param_count_near_paper(params):
    """Paper quotes 21,690; our LeNet variant lands at 21,669 (see DESIGN.md)."""
    n = sum(int(np.prod(p.shape)) for p in params)
    assert n == model.param_count() == 21_669
    assert abs(n - 21_690) <= 25


def test_param_shapes(params):
    assert tuple(tuple(p.shape) for p in params) == model.param_shapes()


def test_forward_shape(params, rng=np.random.default_rng(0)):
    x, _ = _batch(rng, 4)
    logits = model.forward(params, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_uniform_at_init(params):
    """Zero-ish logits => loss ~= ln(10)."""
    rng = np.random.default_rng(1)
    x, y = _batch(rng, 16)
    loss = float(model.loss_fn(params, x, y))
    assert abs(loss - np.log(10)) < 0.5


def test_grads_match_finite_differences(params):
    rng = np.random.default_rng(2)
    x, y = _batch(rng, 4)
    grads = jax.grad(model.loss_fn)(params, x, y)
    # check a handful of coordinates of the fc2 weight by central difference
    w4 = params[6]
    g4 = np.asarray(grads[6])
    eps = 1e-3
    for idx in [(0, 0), (10, 3), (96, 9)]:
        bump = np.zeros_like(np.asarray(w4))
        bump[idx] = eps
        pp = list(params)
        pp[6] = w4 + bump
        lp = float(model.loss_fn(tuple(pp), x, y))
        pp[6] = w4 - bump
        lm = float(model.loss_fn(tuple(pp), x, y))
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - g4[idx]) < 2e-3, (idx, fd, g4[idx])


def test_train_step_reduces_loss(params):
    rng = np.random.default_rng(3)
    x, y = _batch(rng, model.TRAIN_BATCH)
    lr = jnp.float32(0.1)
    state = params
    losses = []
    for _ in range(8):
        out = model.train_step(*state, x, y, lr)
        state, loss = out[:8], out[8]
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


def test_eval_step_counts(params):
    rng = np.random.default_rng(4)
    x, y = _batch(rng, model.EVAL_BATCH)
    loss, correct = model.eval_step(*params, x, y)
    assert 0.0 <= float(correct) <= model.EVAL_BATCH
    assert np.isfinite(float(loss))


def test_init_step_deterministic():
    p1 = model.init_step(jnp.int32(7))
    p2 = model.init_step(jnp.int32(7))
    p3 = model.init_step(jnp.int32(8))
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert any(
        not np.array_equal(np.asarray(a), np.asarray(c)) for a, c in zip(p1, p3)
    )
