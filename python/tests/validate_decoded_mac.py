"""Validation harness for the PR 5 pre-decoded-operand MAC.

Ports the bit-exact PIM softfloat reference (rust/src/fpu/softfloat.rs)
to Python and exhaustively checks the decoded-operand MAC

    pim_mac_acc_dec(acc, pim_decode(w), x)
        == pim_mac_acc_bits(acc, w, x)
        == pim_add(acc, pim_mul(w, x))

where `pim_decode` packs one operand's sign / exponent field /
significand-with-implicit-bit into a single word so the GEMM kernels
can split the weight operand once per panel instead of once per MAC.
The packing must be lossless (`pim_encode` is the exact inverse) and
the decoded MAC must keep the FTZ zero-operand shortcut and the shared
normalise/round core bit for bit.

Run: python3 python/tests/validate_decoded_mac.py
(Repo convention: the authoring container has no Rust toolchain, so the
numerics are pre-validated here; the Rust test
`fpu::softfloat::tests::mac_dec_matches_chain_on_triple_grid` re-checks
the same grids on every `cargo test`.)
"""

QNAN = 0x7FC00000
INF = 0x7F800000
EXP = 0x7F800000
MIN_NORMAL_MANT = 0x00800000
M32 = 0xFFFFFFFF


def fields(bits):
    return (bits >> 31) & 1, (bits >> 23) & 0xFF, bits & 0x7FFFFF


def mul_core_sig(sign, ea, ma, eb, mb):
    """Shared normalise/round core on 24-bit significands (mirrors the
    Rust mul_core_sig exactly)."""
    p = ma * mb
    top_set = (p >> 47) & 1
    s = 23 + top_set
    mant_preround = (p >> s) & 0xFFFFFF
    guard = (p >> (s - 1)) & 1
    sticky = (p & ((1 << (s - 1)) - 1)) != 0
    round_up = guard == 1 and (sticky or (mant_preround & 1) == 1)
    mant = mant_preround + (1 if round_up else 0)
    e = ea + eb - 127 + top_set
    e0 = e
    if mant == 1 << 24:
        mant >>= 1
        e += 1
    if e >= 255:
        return sign | INF
    if e <= 0:
        if e0 == 0 and mant_preround == 0xFFFFFF:
            return sign | MIN_NORMAL_MANT
        return sign
    return sign | (e << 23) | (mant & 0x7FFFFF)


def pim_mul_bits(abits, bbits):
    sa, ea, fa = fields(abits)
    sb, eb, fb = fields(bbits)
    a_nan = ea == 255 and fa != 0
    b_nan = eb == 255 and fb != 0
    a_inf = ea == 255 and fa == 0
    b_inf = eb == 255 and fb == 0
    a_zero = ea == 0
    b_zero = eb == 0
    sign = ((sa ^ sb) << 31) & M32
    if a_nan or b_nan or (a_inf and b_zero) or (b_inf and a_zero):
        return QNAN
    if a_inf or b_inf:
        return sign | INF
    if a_zero or b_zero:
        return sign
    return mul_core_sig(sign, ea, fa | MIN_NORMAL_MANT, eb, fb | MIN_NORMAL_MANT)


def pim_add_bits(abits, bbits):
    sa, ea, fa = fields(abits)
    sb, eb, fb = fields(bbits)
    a_nan = ea == 255 and fa != 0
    b_nan = eb == 255 and fb != 0
    a_inf = ea == 255 and fa == 0
    b_inf = eb == 255 and fb == 0
    a_zero = ea == 0
    b_zero = eb == 0
    if a_nan or b_nan or (a_inf and b_inf and sa != sb):
        return QNAN
    if a_inf:
        return abits
    if b_inf:
        return bbits
    if a_zero and b_zero:
        return ((sa & sb) << 31) & M32
    if a_zero:
        return bbits
    if b_zero:
        return abits

    if (abits & 0x7FFFFFFF) >= (bbits & 0x7FFFFFFF):
        xbits, ybits = abits, bbits
    else:
        xbits, ybits = bbits, abits
    sx, ex, fx = fields(xbits)
    _, ey, fy = fields(ybits)
    mx = (fx | MIN_NORMAL_MANT) << 3
    my = (fy | MIN_NORMAL_MANT) << 3
    d = min(ex - ey, 27)
    lost = my & ((1 << d) - 1)
    my_al = (my >> d) | (1 if lost != 0 else 0)
    subtract = sx != (ybits >> 31) & 1
    total = (mx - my_al) if subtract else (mx + my_al)
    if total == 0:
        return 0
    p = total.bit_length() - 1
    if p == 27:
        total_n, e0 = (total >> 1) | (total & 1), ex + 1
    else:
        total_n, e0 = total << (26 - p), ex - (26 - p)
    kept_preround = total_n >> 3
    rb = (total_n >> 2) & 1
    st = (total_n & 3) != 0
    round_up = rb == 1 and (st or (kept_preround & 1) == 1)
    kept = kept_preround + (1 if round_up else 0)
    e = e0
    if kept == 1 << 24:
        kept >>= 1
        e += 1
    sign = (sx << 31) & M32
    if e >= 255:
        return sign | INF
    if e <= 0:
        if e0 == 0 and kept_preround == 0xFFFFFF:
            return sign | MIN_NORMAL_MANT
        return sign
    return sign | (e << 23) | (kept & 0x7FFFFF)


def pim_mac_acc_bits(acc, w, x):
    """The PR 4 raw-bits shortcut MAC (reference for the decoded one)."""
    we = w & EXP
    xe = x & EXP
    if (we == 0 or xe == 0) and we != EXP and xe != EXP:
        if (acc & EXP) != 0 and (acc & 0x7FFFFFFF) <= INF:
            return acc
        return pim_add_bits(acc, (w ^ x) & 0x80000000)
    return pim_add_bits(acc, pim_mul_bits(w, x))


def pim_decode(bits):
    """Mirror of the Rust pim_decode: significand (implicit bit attached
    for normals) in [23:0], exponent field in [31:24], sign in [32]."""
    e = (bits >> 23) & 0xFF
    f = bits & 0x7FFFFF
    mant = (f | MIN_NORMAL_MANT) if 1 <= e <= 254 else f
    return mant | (e << 24) | (((bits >> 31) & 1) << 32)


def pim_encode(dec):
    return ((((dec >> 32) & 1) << 31) | (((dec >> 24) & 0xFF) << 23) | (dec & 0x7FFFFF)) & M32


def pim_mac_acc_dec(acc, wdec, x):
    """Mirror of the Rust pim_mac_acc_dec, branch for branch."""
    we = (wdec >> 24) & 0xFF
    xe = x & EXP
    if (we == 0 or xe == 0) and we != 255 and xe != EXP:
        if (acc & EXP) != 0 and (acc & 0x7FFFFFFF) <= INF:
            return acc
        wsign = ((wdec >> 32) & 1) << 31
        return pim_add_bits(acc, (wsign ^ x) & 0x80000000)
    xef = (x >> 23) & 0xFF
    if 1 <= we <= 254 and 1 <= xef <= 254:
        sign = ((((wdec >> 32) & 1) ^ ((x >> 31) & 1)) << 31) & M32
        prod = mul_core_sig(sign, we, wdec & 0xFFFFFF, xef, (x & 0x7FFFFF) | MIN_NORMAL_MANT)
        return pim_add_bits(acc, prod)
    return pim_add_bits(acc, pim_mul_bits(pim_encode(wdec), x))


def edge_bit_patterns():
    exps = [0, 1, 2, 127, 253, 254, 255]
    mants = [0, 1, 0x400000, 0x7FFFFF]
    out = []
    for e in exps:
        for m in mants:
            for s in (0, 1):
                out.append(((s << 31) | (e << 23) | m) & M32)
    return out


def main():
    grid = edge_bit_patterns()

    # decode/encode is a lossless pair on every pattern class
    for b in grid:
        assert pim_encode(pim_decode(b)) == b, f"roundtrip {b:#010x}"

    n = 0
    for acc in grid:
        for w in grid:
            wdec = pim_decode(w)
            for x in grid:
                got = pim_mac_acc_dec(acc, wdec, x)
                want = pim_mac_acc_bits(acc, w, x)
                chain = pim_add_bits(acc, pim_mul_bits(w, x))
                assert got == want == chain, (
                    f"mismatch acc={acc:#010x} w={w:#010x} x={x:#010x}: "
                    f"dec={got:#010x} fast={want:#010x} chain={chain:#010x}"
                )
                n += 1
    print(f"edge-grid triples OK: {n}")

    state = 0xDECAF00DCAFED00D
    zero_w = zero_x = 0
    for i in range(300_000):
        state ^= (state << 13) & 0xFFFFFFFFFFFFFFFF
        state ^= state >> 7
        state ^= (state << 17) & 0xFFFFFFFFFFFFFFFF
        acc = state & M32
        state ^= (state << 13) & 0xFFFFFFFFFFFFFFFF
        state ^= state >> 7
        state ^= (state << 17) & 0xFFFFFFFFFFFFFFFF
        w = state & M32
        x = (state >> 32) & M32
        if i % 2 == 0:
            x &= 0x807FFFFF  # force zero-class x on half the samples
        if i % 3 == 0:
            w &= 0x807FFFFF  # and zero-class w (the decoded side) on a third
        assert pim_encode(pim_decode(w)) == w
        got = pim_mac_acc_dec(acc, pim_decode(w), x)
        want = pim_mac_acc_bits(acc, w, x)
        assert got == want, f"random mismatch acc={acc:#010x} w={w:#010x} x={x:#010x}"
        if (w & EXP) == 0:
            zero_w += 1
        if (x & EXP) == 0:
            zero_x += 1
    print(f"random triples OK (zero-class w in {zero_w}, x in {zero_x})")
    print("decoded-operand MAC is bit-identical")


if __name__ == "__main__":
    main()
