"""Validation harness for the PR 4 zero-operand MAC fast path.

Ports the bit-exact PIM softfloat reference (rust/src/fpu/softfloat.rs,
seed reference implementations) to Python and exhaustively checks the
host-side shortcut

    mac(acc, w, x) == pim_add(acc, pim_mul(w, x))

with the skip rule: when either operand is FTZ-zero-class (exponent
field 0) and neither operand is Inf/NaN, the product is a signed zero;
adding a signed zero to a normal-or-infinite acc is the identity, so the
whole MAC can be skipped.  Run: python3 python/tests/validate_mac_skip.py
"""

QNAN = 0x7FC00000
INF = 0x7F800000
MIN_NORMAL_MANT = 0x00800000
M32 = 0xFFFFFFFF


def fields(bits):
    return (bits >> 31) & 1, (bits >> 23) & 0xFF, bits & 0x7FFFFF


def pim_mul_bits(abits, bbits):
    sa, ea, fa = fields(abits)
    sb, eb, fb = fields(bbits)
    a_nan = ea == 255 and fa != 0
    b_nan = eb == 255 and fb != 0
    a_inf = ea == 255 and fa == 0
    b_inf = eb == 255 and fb == 0
    a_zero = ea == 0
    b_zero = eb == 0
    sign = ((sa ^ sb) << 31) & M32
    if a_nan or b_nan or (a_inf and b_zero) or (b_inf and a_zero):
        return QNAN
    if a_inf or b_inf:
        return sign | INF
    if a_zero or b_zero:
        return sign

    ma = fa | MIN_NORMAL_MANT
    mb = fb | MIN_NORMAL_MANT
    p = ma * mb
    top_set = (p >> 47) & 1
    s = 23 + top_set
    mant_preround = (p >> s) & 0xFFFFFF
    guard = (p >> (s - 1)) & 1
    sticky = (p & ((1 << (s - 1)) - 1)) != 0
    round_up = guard == 1 and (sticky or (mant_preround & 1) == 1)
    mant = mant_preround + (1 if round_up else 0)
    e = ea + eb - 127 + top_set
    e0 = e
    if mant == 1 << 24:
        mant >>= 1
        e += 1
    if e >= 255:
        return sign | INF
    if e <= 0:
        if e0 == 0 and mant_preround == 0xFFFFFF:
            return sign | MIN_NORMAL_MANT
        return sign
    return sign | (e << 23) | (mant & 0x7FFFFF)


def pim_add_bits(abits, bbits):
    sa, ea, fa = fields(abits)
    sb, eb, fb = fields(bbits)
    a_nan = ea == 255 and fa != 0
    b_nan = eb == 255 and fb != 0
    a_inf = ea == 255 and fa == 0
    b_inf = eb == 255 and fb == 0
    a_zero = ea == 0
    b_zero = eb == 0
    if a_nan or b_nan or (a_inf and b_inf and sa != sb):
        return QNAN
    if a_inf:
        return abits
    if b_inf:
        return bbits
    if a_zero and b_zero:
        return ((sa & sb) << 31) & M32
    if a_zero:
        return bbits
    if b_zero:
        return abits

    if (abits & 0x7FFFFFFF) >= (bbits & 0x7FFFFFFF):
        xbits, ybits = abits, bbits
    else:
        xbits, ybits = bbits, abits
    sx, ex, fx = fields(xbits)
    _, ey, fy = fields(ybits)
    mx = (fx | MIN_NORMAL_MANT) << 3
    my = (fy | MIN_NORMAL_MANT) << 3
    d = min(ex - ey, 27)
    lost = my & ((1 << d) - 1)
    my_al = (my >> d) | (1 if lost != 0 else 0)
    subtract = sx != (ybits >> 31) & 1
    total = (mx - my_al) if subtract else (mx + my_al)
    if total == 0:
        return 0
    p = total.bit_length() - 1
    if p == 27:
        total_n, e0 = (total >> 1) | (total & 1), ex + 1
    else:
        total_n, e0 = total << (26 - p), ex - (26 - p)
    kept_preround = total_n >> 3
    rb = (total_n >> 2) & 1
    st = (total_n & 3) != 0
    round_up = rb == 1 and (st or (kept_preround & 1) == 1)
    kept = kept_preround + (1 if round_up else 0)
    e = e0
    if kept == 1 << 24:
        kept >>= 1
        e += 1
    sign = (sx << 31) & M32
    if e >= 255:
        return sign | INF
    if e <= 0:
        if e0 == 0 and kept_preround == 0xFFFFFF:
            return sign | MIN_NORMAL_MANT
        return sign
    return sign | (e << 23) | (kept & 0x7FFFFF)


def mac_reference(acc, w, x):
    return pim_add_bits(acc, pim_mul_bits(w, x))


def mac_fast(acc, w, x):
    """The Rust pim_mac_acc_bits shortcut, mirrored exactly."""
    EXP = 0x7F800000
    we = w & EXP
    xe = x & EXP
    if (we == 0 or xe == 0) and we != EXP and xe != EXP:
        # product is a signed zero
        if (acc & EXP) != 0 and (acc & 0x7FFFFFFF) <= INF:
            return acc  # normal or +-Inf acc: identity
        return pim_add_bits(acc, (w ^ x) & 0x80000000)
    return pim_add_bits(acc, pim_mul_bits(w, x))


def edge_bit_patterns():
    exps = [0, 1, 2, 127, 253, 254, 255]
    mants = [0, 1, 0x400000, 0x7FFFFF]
    out = []
    for e in exps:
        for m in mants:
            for s in (0, 1):
                out.append(((s << 31) | (e << 23) | m) & M32)
    return out


def main():
    grid = edge_bit_patterns()
    n = 0
    for acc in grid:
        for w in grid:
            for x in grid:
                got = mac_fast(acc, w, x)
                want = mac_reference(acc, w, x)
                assert got == want, (
                    f"mismatch acc={acc:#010x} w={w:#010x} x={x:#010x}: "
                    f"fast={got:#010x} ref={want:#010x}"
                )
                n += 1
    print(f"edge-grid triples OK: {n}")

    state = 0x5EEDF00DCAFED00D
    skipped = 0
    for i in range(300_000):
        state ^= (state << 13) & 0xFFFFFFFFFFFFFFFF
        state ^= state >> 7
        state ^= (state << 17) & 0xFFFFFFFFFFFFFFFF
        acc = state & M32
        state ^= (state << 13) & 0xFFFFFFFFFFFFFFFF
        state ^= state >> 7
        state ^= (state << 17) & 0xFFFFFFFFFFFFFFFF
        w = state & M32
        # make zero-class x common: force exponent field to 0 on half
        x = (state >> 32) & M32
        if i % 2 == 0:
            x &= 0x807FFFFF
        got = mac_fast(acc, w, x)
        want = mac_reference(acc, w, x)
        assert got == want, (
            f"random mismatch acc={acc:#010x} w={w:#010x} x={x:#010x}"
        )
        if (x & 0x7F800000) == 0:
            skipped += 1
    print(f"random triples OK (zero-class x in {skipped})")
    print("mac skip rule is bit-identical")


if __name__ == "__main__":
    main()
