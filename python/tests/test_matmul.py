"""Pallas matmul kernel vs the jnp oracle: shapes, dtypes, VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.matmul import matmul, matmul_jit
from compile.kernels.ref import matmul_ref


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return x.astype(dtype)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 8),
        (128, 128, 128),
        (5, 7, 3),          # nothing aligned
        (130, 150, 97),     # straddles block boundaries
        (32, 1152, 10),     # LeNet fc-ish
        (256, 192, 97),
    ],
)
def test_shapes_f32(rng, m, k, n):
    a, b = _rand(rng, (m, k), np.float32), _rand(rng, (k, n), np.float32)
    got = np.asarray(matmul_jit(a, b))
    want = np.asarray(matmul_ref(a, b))
    # accumulation order differs between the tiled kernel and the oracle;
    # scale the absolute tolerance with the contraction length
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5 * np.sqrt(k))


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_dtypes(rng, dtype):
    a = jnp.asarray(_rand(rng, (33, 65), np.float32)).astype(dtype)
    b = jnp.asarray(_rand(rng, (65, 17), np.float32)).astype(dtype)
    got = np.asarray(matmul_jit(a, b), np.float32)
    want = np.asarray(matmul_ref(a, b), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20)
def test_hypothesis_shape_sweep(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = np.asarray(matmul_jit(a, b))
    want = np.asarray(matmul_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn", [(8, 128), (16, 128), (128, 256)])
def test_block_shape_invariance(rng, bm, bn):
    """Result must not depend on the BlockSpec tiling."""
    a, b = _rand(rng, (100, 60), np.float32), _rand(rng, (60, 140), np.float32)
    got = np.asarray(matmul_jit(a, b, block_m=bm, block_n=bn))
    want = np.asarray(matmul_jit(a, b))
    np.testing.assert_array_equal(got, want)


def test_vjp_matches_jnp(rng):
    a = _rand(rng, (12, 20), np.float32)
    b = _rand(rng, (20, 9), np.float32)
    g = _rand(rng, (12, 9), np.float32)

    def ours(a, b):
        return jnp.vdot(matmul(a, b), g)

    def theirs(a, b):
        return jnp.vdot(jnp.matmul(a, b), g)

    da1, db1 = jax.grad(ours, argnums=(0, 1))(a, b)
    da2, db2 = jax.grad(theirs, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(da1), np.asarray(da2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(db1), np.asarray(db2), rtol=1e-5, atol=1e-6)


def test_grad_through_chain(rng):
    """Two chained kernel matmuls differentiate like the jnp chain."""
    a = _rand(rng, (6, 8), np.float32)
    w1 = _rand(rng, (8, 16), np.float32)
    w2 = _rand(rng, (16, 4), np.float32)

    ours = lambda w1, w2: jnp.sum(matmul(jax.nn.relu(matmul(a, w1)), w2) ** 2)
    ref = lambda w1, w2: jnp.sum((jax.nn.relu(a @ w1) @ w2) ** 2)
    g1 = jax.grad(ours, argnums=(0, 1))(w1, w2)
    g2 = jax.grad(ref, argnums=(0, 1))(w1, w2)
    for x, y in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-5)
