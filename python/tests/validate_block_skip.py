"""Validation harness for the PR 10 block-sparse panel skip.

Ports the bit-exact PIM softfloat reference (rust/src/fpu/softfloat.rs)
to Python and proves the block-skip algebra used by the masked resident
panel kernels in rust/src/arch/gemm.rs:

  * ``fold_zero_run``: folding a run of ``acc + w*x`` MACs where the
    weight is a pruned (+0.0) block entry is NOT an unconditional
    identity -- a zero-class (+-0 / subnormal) accumulator can flip sign
    or flush, and an Inf/NaN activation makes the product QNAN.  The
    fold handles the first two exactly and refuses (dense fallback) on
    the third.
  * the masked NT (forward), NN (dgrad) and TN (wgrad, output-skip)
    kernel loops, mirrored structure-for-structure, are bit-identical
    to flat ascending-k dense chains over a weight matrix whose masked
    blocks are densified to +0.0 (NT/NN), and to the seed-projection
    for TN.
  * SGD with masked updates keeps pruned blocks pinned at +0.0 and is
    bit-identical to a dense update followed by re-zeroing (projection).

Run: python3 python/tests/validate_block_skip.py
"""

QNAN = 0x7FC00000
INF = 0x7F800000
EXP = 0x7F800000
MIN_NORMAL_MANT = 0x00800000
M32 = 0xFFFFFFFF


def fields(bits):
    return (bits >> 31) & 1, (bits >> 23) & 0xFF, bits & 0x7FFFFF


def pim_mul_bits(abits, bbits):
    sa, ea, fa = fields(abits)
    sb, eb, fb = fields(bbits)
    a_nan = ea == 255 and fa != 0
    b_nan = eb == 255 and fb != 0
    a_inf = ea == 255 and fa == 0
    b_inf = eb == 255 and fb == 0
    a_zero = ea == 0
    b_zero = eb == 0
    sign = ((sa ^ sb) << 31) & M32
    if a_nan or b_nan or (a_inf and b_zero) or (b_inf and a_zero):
        return QNAN
    if a_inf or b_inf:
        return sign | INF
    if a_zero or b_zero:
        return sign

    ma = fa | MIN_NORMAL_MANT
    mb = fb | MIN_NORMAL_MANT
    p = ma * mb
    top_set = (p >> 47) & 1
    s = 23 + top_set
    mant_preround = (p >> s) & 0xFFFFFF
    guard = (p >> (s - 1)) & 1
    sticky = (p & ((1 << (s - 1)) - 1)) != 0
    round_up = guard == 1 and (sticky or (mant_preround & 1) == 1)
    mant = mant_preround + (1 if round_up else 0)
    e = ea + eb - 127 + top_set
    e0 = e
    if mant == 1 << 24:
        mant >>= 1
        e += 1
    if e >= 255:
        return sign | INF
    if e <= 0:
        if e0 == 0 and mant_preround == 0xFFFFFF:
            return sign | MIN_NORMAL_MANT
        return sign
    return sign | (e << 23) | (mant & 0x7FFFFF)


def pim_add_bits(abits, bbits):
    sa, ea, fa = fields(abits)
    sb, eb, fb = fields(bbits)
    a_nan = ea == 255 and fa != 0
    b_nan = eb == 255 and fb != 0
    a_inf = ea == 255 and fa == 0
    b_inf = eb == 255 and fb == 0
    a_zero = ea == 0
    b_zero = eb == 0
    if a_nan or b_nan or (a_inf and b_inf and sa != sb):
        return QNAN
    if a_inf:
        return abits
    if b_inf:
        return bbits
    if a_zero and b_zero:
        return ((sa & sb) << 31) & M32
    if a_zero:
        return bbits
    if b_zero:
        return abits

    if (abits & 0x7FFFFFFF) >= (bbits & 0x7FFFFFFF):
        xbits, ybits = abits, bbits
    else:
        xbits, ybits = bbits, abits
    sx, ex, fx = fields(xbits)
    _, ey, fy = fields(ybits)
    mx = (fx | MIN_NORMAL_MANT) << 3
    my = (fy | MIN_NORMAL_MANT) << 3
    d = min(ex - ey, 27)
    lost = my & ((1 << d) - 1)
    my_al = (my >> d) | (1 if lost != 0 else 0)
    subtract = sx != (ybits >> 31) & 1
    total = (mx - my_al) if subtract else (mx + my_al)
    if total == 0:
        return 0
    p = total.bit_length() - 1
    if p == 27:
        total_n, e0 = (total >> 1) | (total & 1), ex + 1
    else:
        total_n, e0 = total << (26 - p), ex - (26 - p)
    kept_preround = total_n >> 3
    rb = (total_n >> 2) & 1
    st = (total_n & 3) != 0
    round_up = rb == 1 and (st or (kept_preround & 1) == 1)
    kept = kept_preround + (1 if round_up else 0)
    e = e0
    if kept == 1 << 24:
        kept >>= 1
        e += 1
    sign = (sx << 31) & M32
    if e >= 255:
        return sign | INF
    if e <= 0:
        if e0 == 0 and kept_preround == 0xFFFFFF:
            return sign | MIN_NORMAL_MANT
        return sign
    return sign | (e << 23) | (kept & 0x7FFFFF)


def mac_reference(acc, w, x):
    return pim_add_bits(acc, pim_mul_bits(w, x))


def mac_fast(acc, w, x):
    """The Rust pim_mac_acc shortcut, mirrored exactly (proven by PR 4)."""
    we = w & EXP
    xe = x & EXP
    if (we == 0 or xe == 0) and we != EXP and xe != EXP:
        if (acc & EXP) != 0 and (acc & 0x7FFFFFFF) <= INF:
            return acc
        return pim_add_bits(acc, (w ^ x) & 0x80000000)
    return pim_add_bits(acc, pim_mul_bits(w, x))


def sgd_bits(w, lr, g):
    """w - lr*g via the PIM mul/sub chain (pim_sub = add of negation)."""
    return pim_add_bits(w, pim_mul_bits(lr, g) ^ 0x80000000)


# ---------------------------------------------------------------------------
# Block-skip algebra (mirrors rust/src/arch/sparsity.rs helpers)
# ---------------------------------------------------------------------------


def skip_flags(xs):
    """(all_finite, any_pos) over a run of activation bit patterns."""
    all_finite = True
    any_pos = False
    for x in xs:
        if x & EXP == EXP:
            all_finite = False
        if (x >> 31) == 0:
            any_pos = True
    return all_finite, any_pos


def fold_zero_run(acc, all_finite, any_pos):
    """Result of acc after a run (len >= 1) of +0-weight MACs, or None.

    None means an activation in the run is Inf/NaN (product would be
    QNAN) and the caller must fall back to the dense MAC loop.
    """
    if not all_finite:
        return None
    if acc & EXP == EXP:
        if acc & 0x007FFFFF:
            return QNAN  # NaN acc: any add collapses to the canonical QNAN
        return acc  # +-Inf acc: identity
    if acc & EXP:
        return acc  # normal acc: signed-zero adds are identities
    # zero-class acc (+-0 or subnormal): (sa & sb) chain; stays -0 only if
    # the acc is negative and every product in the run is -0.
    return 0x80000000 if (acc >> 31) == 1 and not any_pos else 0


# ---------------------------------------------------------------------------
# Masked kernel mirrors (structure-for-structure with arch/gemm.rs)
# ---------------------------------------------------------------------------


def nt_masked(a, w, bias, masked, m, k, n, br, kc):
    """Forward y = x . W^T with block skip.  w row-major [n, k]."""
    y = [[(bias[j] if bias is not None else 0) for j in range(n)] for _ in range(m)]
    kp = 0
    while kp < k:
        kend = min(kp + kc, k)
        gc = kp // kc
        for r in range(m):
            xrow = a[r * k + kp : r * k + kend]
            flags = None
            for j in range(n):
                if (j // br, gc) in masked:
                    if flags is None:
                        flags = skip_flags(xrow)
                    all_finite, any_pos = flags
                    v = fold_zero_run(y[r][j], all_finite, any_pos)
                    if v is None:
                        acc = y[r][j]
                        for kk in range(kp, kend):
                            acc = mac_fast(acc, w[j * k + kk], a[r * k + kk])
                        y[r][j] = acc
                    else:
                        y[r][j] = v
                else:
                    acc = y[r][j]
                    for kk in range(kp, kend):
                        acc = mac_fast(acc, w[j * k + kk], a[r * k + kk])
                    y[r][j] = acc
        kp = kend
    return y


def nt_dense(a, w, bias, m, k, n):
    y = []
    for r in range(m):
        row = []
        for j in range(n):
            acc = bias[j] if bias is not None else 0
            for kk in range(k):
                acc = mac_fast(acc, w[j * k + kk], a[r * k + kk])
            row.append(acc)
        y.append(row)
    return y


def nn_masked(a, w, masked, m, k, n, br, kc):
    """dgrad y = delta . W with block skip.  w read as [k, n] = [out, inp]."""
    y = [[0] * n for _ in range(m)]
    for r in range(m):
        arow = a[r * k : (r + 1) * k]
        ka = 0
        while ka < k:
            gr = ka // br
            kb = min((gr + 1) * br, k)
            flags = None
            j = 0
            while j < n:
                gc = j // kc
                jend = min((gc + 1) * kc, n)
                if (gr, gc) in masked:
                    if flags is None:
                        flags = skip_flags(arow[ka:kb])
                    all_finite, any_pos = flags
                    if all_finite:
                        for jj in range(j, jend):
                            y[r][jj] = fold_zero_run(y[r][jj], True, any_pos)
                    else:
                        for kk in range(ka, kb):
                            av = arow[kk]
                            for jj in range(j, jend):
                                y[r][jj] = mac_fast(y[r][jj], w[kk * n + jj], av)
                else:
                    for kk in range(ka, kb):
                        av = arow[kk]
                        for jj in range(j, jend):
                            y[r][jj] = mac_fast(y[r][jj], w[kk * n + jj], av)
                j = jend
            ka = kb
    return y


def nn_dense(a, w, m, k, n):
    y = []
    for r in range(m):
        row = []
        for j in range(n):
            acc = 0
            for kk in range(k):
                acc = mac_fast(acc, w[kk * n + j], a[r * k + kk])
            row.append(acc)
        y.append(row)
    return y


def tn_masked(a, b, seed, masked, m, k, n, br, kc):
    """wgrad dW = delta^T . X with OUTPUT skip: masked cells keep the seed.

    a is [k, m] (delta, batch-major), b is [k, n] (x), output [m, n] has
    the weight-matrix shape, so the weight mask applies to it directly.
    """
    y = [
        [(seed[r][j] if seed is not None else 0) for j in range(n)]
        for r in range(m)
    ]
    for kk in range(k):
        for r in range(m):
            gr = r // br
            ad = a[kk * m + r]
            j = 0
            while j < n:
                gc = j // kc
                jend = min((gc + 1) * kc, n)
                if (gr, gc) not in masked:
                    for jj in range(j, jend):
                        y[r][jj] = mac_fast(y[r][jj], ad, b[kk * n + jj])
                j = jend
    return y


def tn_dense(a, b, seed, m, k, n):
    y = []
    for r in range(m):
        row = []
        for j in range(n):
            acc = seed[r][j] if seed is not None else 0
            for kk in range(k):
                acc = mac_fast(acc, a[kk * m + r], b[kk * n + j])
            row.append(acc)
        y.append(row)
    return y


# ---------------------------------------------------------------------------
# Data generation
# ---------------------------------------------------------------------------


class Rng:
    def __init__(self, seed):
        self.s = seed & 0xFFFFFFFFFFFFFFFF

    def next(self):
        self.s ^= (self.s << 13) & 0xFFFFFFFFFFFFFFFF
        self.s ^= self.s >> 7
        self.s ^= (self.s << 17) & 0xFFFFFFFFFFFFFFFF
        return self.s

    def bits(self, specials=()):
        """A finite fp32 pattern; occasionally a special from `specials`."""
        r = self.next()
        if specials and r % 11 == 0:
            return specials[(r >> 8) % len(specials)]
        c = (r >> 4) % 8
        sign = (r >> 63) << 31
        mant = (r >> 24) & 0x7FFFFF
        if c == 0:
            return sign  # +-0
        if c == 1:
            return (sign | (mant & 0xFFF)) & M32  # subnormal
        exp = 100 + (r >> 40) % 56  # normals across ~56 binades
        return (sign | (exp << 23) | mant) & M32


def edge_bit_patterns():
    exps = [0, 1, 2, 127, 253, 254, 255]
    mants = [0, 1, 0x400000, 0x7FFFFF]
    out = []
    for e in exps:
        for m in mants:
            for s in (0, 1):
                out.append(((s << 31) | (e << 23) | m) & M32)
    return out


def random_mask(rng, grid_r, grid_c, ratio):
    nb = grid_r * grid_c
    target = int(nb * ratio)
    order = sorted(range(nb), key=lambda i: (rng.next(), i))
    return {(i // grid_c, i % grid_c) for i in order[:target]}


def zero_masked_w_nt(w, masked, n, k, br, kc):
    out = list(w)
    for j in range(n):
        for kk in range(k):
            if (j // br, kk // kc) in masked:
                out[j * k + kk] = 0
    return out


def zero_masked_w_nn(w, masked, kdim, n, br, kc):
    out = list(w)
    for kk in range(kdim):
        for j in range(n):
            if (kk // br, j // kc) in masked:
                out[kk * n + j] = 0
    return out


# ---------------------------------------------------------------------------
# Tests
# ---------------------------------------------------------------------------


def check_fold_rule():
    grid = edge_bit_patterns()
    finite = [g for g in grid if g & EXP != EXP]
    n = 0
    # exhaustive length-1 runs, strided length-2, random length-1..4
    for acc in grid:
        for x0 in grid:
            n += check_one_run(acc, [x0])
    for acc in grid:
        for x0 in finite[::2]:
            for x1 in finite[1::2]:
                n += check_one_run(acc, [x0, x1])
    rng = Rng(0xB10C5EED)
    for _ in range(20_000):
        acc = rng.bits(specials=(0, 0x80000000, INF, QNAN, 0x00000001, 0x80000001))
        ln = 1 + rng.next() % 4
        run = [
            rng.bits(specials=(0, 0x80000000, INF, INF | 0x80000000, QNAN))
            for _ in range(ln)
        ]
        n += check_one_run(acc, run)
    print(f"fold-rule runs OK: {n}")


def check_one_run(acc, run):
    all_finite, any_pos = skip_flags(run)
    seq = acc
    for x in run:
        seq = mac_reference(seq, 0, x)  # +0 weight: the pruned block entry
    got = fold_zero_run(acc, all_finite, any_pos)
    if got is None:
        assert not all_finite, "fold refused a finite run"
        return 1
    assert got == seq, (
        f"fold mismatch acc={acc:#010x} run={[hex(x) for x in run]}: "
        f"fold={got:#010x} seq={seq:#010x}"
    )
    return 1


def check_nt(rng, m, k, n, br, kc, masked, specials, bias_specials, tag):
    a = [rng.bits(specials=specials) for _ in range(m * k)]
    w = [rng.bits() for _ in range(n * k)]
    w = zero_masked_w_nt(w, masked, n, k, br, kc)
    bias = [rng.bits(specials=bias_specials) for _ in range(n)]
    got = nt_masked(a, w, bias, masked, m, k, n, br, kc)
    want = nt_dense(a, w, bias, m, k, n)
    assert got == want, f"NT mismatch [{tag}] masked={sorted(masked)}"


def check_nn(rng, m, k, n, br, kc, masked, specials, tag):
    a = [rng.bits(specials=specials) for _ in range(m * k)]
    w = [rng.bits() for _ in range(k * n)]
    w = zero_masked_w_nn(w, masked, k, n, br, kc)
    got = nn_masked(a, w, masked, m, k, n, br, kc)
    want = nn_dense(a, w, m, k, n)
    assert got == want, f"NN mismatch [{tag}] masked={sorted(masked)}"


def check_tn(rng, m, k, n, br, kc, masked, with_seed, tag):
    a = [rng.bits() for _ in range(k * m)]
    b = [rng.bits() for _ in range(k * n)]
    seed = (
        [[rng.bits() for _ in range(n)] for _ in range(m)] if with_seed else None
    )
    got = tn_masked(a, b, seed, masked, m, k, n, br, kc)
    want = tn_dense(a, b, seed, m, k, n)
    for r in range(m):
        for j in range(n):
            if (r // br, j // kc) in masked:
                expect = seed[r][j] if seed is not None else 0
                assert got[r][j] == expect, f"TN masked cell not seed [{tag}]"
            else:
                assert got[r][j] == want[r][j], f"TN live mismatch [{tag}]"


def check_kernels():
    kc, br = 8, 3
    m, k, n = 3, 2 * kc + 3, 2 * br + 1  # partial edge blocks on both axes
    grid_r = (n + br - 1) // br
    grid_c = (k + kc - 1) // kc
    neg_only = [0x80000000 | (120 << 23) | 0x123456, 0x80000000, 0x80000001]
    cases = 0
    rng = Rng(0xD15EA5E0B10C)
    for ratio in (0.0, 0.4, 0.75, 1.0):
        for trial in range(6):
            masked = random_mask(rng, grid_r, grid_c, ratio)
            specials = (0, 0x80000000, 0x00000001, 0x80000001)
            check_nt(rng, m, k, n, br, kc, masked, specials, specials, "mixed")
            cases += 1
    # NN: weight read as [k=out, n=inp]; mask grid is (out_block, inp_panel)
    kdim, ndim = 2 * br + 1, 2 * kc + 3
    grid_r_nn = (kdim + br - 1) // br
    grid_c_nn = (ndim + kc - 1) // kc
    for ratio in (0.0, 0.4, 0.75, 1.0):
        for trial in range(6):
            masked = random_mask(rng, grid_r_nn, grid_c_nn, ratio)
            specials = (0, 0x80000000, 0x00000001, 0x80000001)
            check_nn(rng, 3, kdim, ndim, br, kc, masked, specials, "mixed")
            cases += 1
    # TN: output [m=out, n=k_in] masked directly
    grid_r_tn = (n + br - 1) // br
    grid_c_tn = (k + kc - 1) // kc
    for ratio in (0.0, 0.5, 1.0):
        for with_seed in (False, True):
            masked = random_mask(rng, grid_r_tn, grid_c_tn, ratio)
            check_tn(rng, n, 4, k, br, kc, masked, with_seed, "mixed")
            cases += 1

    # targeted edge batteries ----------------------------------------------
    full = {(gr, gc) for gr in range(grid_r) for gc in range(grid_c)}
    # all-negative activations: any_pos=False path (acc can stay -0)
    a = [0x80000000 | ((110 + i % 30) << 23) | (i * 2654435761 & 0x7FFFFF)
         for i in range(m * k)]
    for i in range(0, m * k, 5):
        a[i] = 0x80000000  # sprinkle -0 activations
    w = zero_masked_w_nt([rng.bits() for _ in range(n * k)], full, n, k, br, kc)
    bias = [0x80000000, 0x80000001, 0, 0x00000001, 0x80000000, 0, 0x80000002]
    got = nt_masked(a, w, bias, full, m, k, n, br, kc)
    want = nt_dense(a, w, bias, m, k, n)
    assert got == want, "NT all-masked/neg-activation mismatch"
    for r in range(m):
        for j in range(n):
            assert got[r][j] in (0, 0x80000000), "fully-masked NT must fold to a signed zero"
    cases += 1
    # Inf/NaN activations force the dense fallback
    specials = (INF, INF | 0x80000000, QNAN)
    masked = random_mask(rng, grid_r, grid_c, 0.6)
    check_nt(rng, m, k, n, br, kc, masked, specials, (0x80000000,), "nonfinite")
    check_nn(rng, 3, kdim, ndim, br, kc,
             random_mask(rng, grid_r_nn, grid_c_nn, 0.6), specials, "nonfinite")
    cases += 2
    # full-KC panel crossing (the real KC=256), small n
    masked = {(0, 0), (1, 1)}
    check_nt(rng, 2, 300, 5, 2, 256, masked, (0, 0x80000000), (0,), "kc256")
    cases += 1
    print(f"kernel mirrors OK: {cases} cases")


def check_sgd_pinning():
    """3-step single-layer loop: masked kernels + masked SGD == dense projection."""
    rng = Rng(0xF00D5EED)
    kc, br = 8, 2
    batch, inp, out = 3, 2 * kc + 3, 2 * br + 1
    grid_r = (out + br - 1) // br
    grid_c = (inp + kc - 1) // kc
    masked = random_mask(rng, grid_r, grid_c, 0.5)
    lr = 0x3C23D70A  # 0.01f
    w = zero_masked_w_nt([rng.bits() for _ in range(out * inp)], masked, out, inp, br, kc)
    wd = list(w)  # dense-projection replica
    x = [rng.bits(specials=(0, 0x80000000)) for _ in range(batch * inp)]
    for step in range(3):
        ys = nt_masked(x, w, None, masked, batch, inp, out, br, kc)
        yd = nt_dense(x, wd, None, batch, inp, out)
        assert ys == yd, f"fwd diverged at step {step}"
        # synthetic upstream delta, same for both
        delta = [rng.bits() for _ in range(batch * out)]
        gs = tn_masked(delta, x, None, masked, out, batch, inp, br, kc)
        gd = tn_dense(delta, x, None, out, batch, inp)
        for r in range(out):
            for j in range(inp):
                if (r // br, j // kc) in masked:
                    assert gs[r][j] == 0, "masked grad must be +0.0"
                    # dense update then re-zero (projection)
                    wd[r * inp + j] = 0
                else:
                    assert gs[r][j] == gd[r][j], "live grad diverged"
                    w[r * inp + j] = sgd_bits(w[r * inp + j], lr, gs[r][j])
                    wd[r * inp + j] = sgd_bits(wd[r * inp + j], lr, gd[r][j])
    assert w == wd, "post-SGD params diverged from the dense projection"
    for r in range(out):
        for j in range(inp):
            if (r // br, j // kc) in masked:
                assert w[r * inp + j] == 0, "pruned weight drifted off +0.0"
    print("SGD pinning / dense-projection OK: 3 steps bit-identical")


def main():
    check_fold_rule()
    check_kernels()
    check_sgd_pinning()
    print("block-skip algebra and masked kernels are bit-identical")


if __name__ == "__main__":
    main()
