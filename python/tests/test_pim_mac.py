"""Bit-exactness of the PIM datapath emulation kernel vs host IEEE-754.

This is the certification that the paper's section 3.3 procedures
(shift-and-add mantissa multiply, search-aligned mantissa add) compute
true fp32 round-to-nearest-even results under the FTZ convention.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import pim_mac, ref
from .conftest import assert_bits_equal

N = pim_mac.LANES

EDGE = np.array(
    [
        0.0, -0.0, 1.0, -1.0, 2.0, 0.5, 1.5,
        np.inf, -np.inf, np.nan,
        3.4028235e38, -3.4028235e38,          # max normal
        1.1754944e-38, 2.3509887e-38,          # min normal, 2x min normal
        1e-40, -1e-40,                          # subnormals (FTZ to 0)
        1.0000001, 0.99999994,                  # ulp neighbours of 1
        16777216.0, 16777215.0,                 # 2^24 boundary
        np.pi, np.e, 1 / 3, -1 / 3,
    ],
    dtype=np.float32,
)


def _pad(x):
    out = np.zeros(N, np.float32)
    out[: len(x)] = x
    return out


def _pairs(rng, n, lo=-40, hi=40):
    a = (rng.standard_normal(n) * np.exp2(rng.integers(lo, hi, n))).astype(np.float32)
    return a


class TestMul:
    def test_edge_grid(self):
        """Every edge value against every edge value."""
        a, b = np.meshgrid(EDGE, EDGE)
        a, b = a.ravel(), b.ravel()
        pad = (-len(a)) % N
        a = np.concatenate([a, np.ones(pad, np.float32)])
        b = np.concatenate([b, np.ones(pad, np.float32)])
        got = np.asarray(pim_mac.pim_mul_f32(a, b))
        assert_bits_equal(got, ref.pim_mul_ref(a, b), "mul edge grid:")

    @given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([5, 20, 38]))
    @settings(max_examples=12)
    def test_hypothesis_random(self, seed, scale):
        rng = np.random.default_rng(seed)
        a = _pairs(rng, N, -scale, scale)
        b = _pairs(rng, N, -scale, scale)
        got = np.asarray(pim_mac.pim_mul_f32(a, b))
        assert_bits_equal(got, ref.pim_mul_ref(a, b), f"mul seed={seed}:")

    def test_overflow_to_inf(self):
        a = _pad(np.array([2e38, -2e38, 2e38], np.float32))
        b = _pad(np.array([3.0, 3.0, -3.0], np.float32))
        got = np.asarray(pim_mac.pim_mul_f32(a, b))[:3]
        assert np.isposinf(got[0]) and np.isneginf(got[1]) and np.isneginf(got[2])

    def test_underflow_ftz(self):
        a = _pad(np.array([1.2e-38, -1.2e-38], np.float32))
        b = _pad(np.array([0.5, 0.5], np.float32))
        got = np.asarray(pim_mac.pim_mul_f32(a, b))[:2]
        bits = got.view(np.uint32)
        assert bits[0] == 0x00000000 and bits[1] == 0x80000000

    def test_rounding_ties_to_even(self):
        # 1.0000001 * 1.0000001: exercises the guard/sticky path.
        vals = np.float32([1.0000001, 1.9999999, 1.5, 16777215.0])
        a = _pad(vals)
        got = np.asarray(pim_mac.pim_mul_f32(a, a))[:4]
        assert_bits_equal(got, ref.pim_mul_ref(vals, vals), "RNE:")


class TestAdd:
    def test_edge_grid(self):
        a, b = np.meshgrid(EDGE, EDGE)
        a, b = a.ravel(), b.ravel()
        pad = (-len(a)) % N
        a = np.concatenate([a, np.ones(pad, np.float32)])
        b = np.concatenate([b, np.ones(pad, np.float32)])
        got = np.asarray(pim_mac.pim_add_f32(a, b))
        assert_bits_equal(got, ref.pim_add_ref(a, b), "add edge grid:")

    @given(seed=st.integers(0, 2**31 - 1), scale=st.sampled_from([3, 20, 38]))
    @settings(max_examples=12)
    def test_hypothesis_random(self, seed, scale):
        rng = np.random.default_rng(seed)
        a = _pairs(rng, N, -scale, scale)
        b = _pairs(rng, N, -scale, scale)
        got = np.asarray(pim_mac.pim_add_f32(a, b))
        assert_bits_equal(got, ref.pim_add_ref(a, b), f"add seed={seed}:")

    def test_exact_cancellation_gives_pos_zero(self):
        a = _pad(np.array([1.5, -1.5, 42.0], np.float32))
        b = _pad(np.array([-1.5, 1.5, -42.0], np.float32))
        got = np.asarray(pim_mac.pim_add_f32(a, b))[:3]
        assert (got.view(np.uint32)[:3] == 0).all()

    def test_near_cancellation(self):
        """Catastrophic cancellation: result needs a long left renormalise."""
        vals_a = np.float32([1.0000001, 16777216.0, 3.0000002])
        vals_b = np.float32([-1.0, -16777215.0, -3.0])
        got = np.asarray(pim_mac.pim_add_f32(_pad(vals_a), _pad(vals_b)))[:3]
        assert_bits_equal(got, ref.pim_add_ref(vals_a, vals_b), "cancel:")

    def test_tiny_plus_huge_is_huge(self):
        a = _pad(np.float32([1e30, -1e30]))
        b = _pad(np.float32([1.0, 1.0]))
        got = np.asarray(pim_mac.pim_add_f32(a, b))[:2]
        assert_bits_equal(got, ref.pim_add_ref(a[:2], b[:2]), "huge+tiny:")

    def test_subnormal_flush_keeps_sign(self):
        """min_normal - (min_normal + ulp) = -1 subnormal ulp -> -0."""
        mn = np.float32(1.1754944e-38)
        mn_ulp = np.uint32(0x00800001).view(np.float32)
        a = _pad(np.array([mn, -mn], np.float32))
        b = _pad(np.array([-mn_ulp, mn_ulp], np.float32))
        got = np.asarray(pim_mac.pim_add_f32(a, b))[:2]
        assert got.view(np.uint32)[0] == 0x80000000, hex(got.view(np.uint32)[0])
        assert got.view(np.uint32)[1] == 0x00000000
        assert_bits_equal(got, ref.pim_add_ref(a[:2], b[:2]), "signed flush:")

    def test_inf_minus_inf_is_nan(self):
        a = _pad(np.float32([np.inf]))
        b = _pad(np.float32([-np.inf]))
        got = np.asarray(pim_mac.pim_add_f32(a, b))[0]
        assert np.isnan(got)


class TestMacComposition:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=8)
    def test_mac_two_roundings(self, seed):
        """mac(a,b,c) must equal round(round(a*b)+c) on the host, too."""
        rng = np.random.default_rng(seed)
        a, b, c = (_pairs(rng, N, -10, 10) for _ in range(3))
        import jax
        import jax.numpy as jnp

        abits = jax.lax.bitcast_convert_type(jnp.asarray(a), pim_mac.U32)
        bbits = jax.lax.bitcast_convert_type(jnp.asarray(b), pim_mac.U32)
        cbits = jax.lax.bitcast_convert_type(jnp.asarray(c), pim_mac.U32)
        got_bits = pim_mac.mac_bits(abits, bbits, cbits)
        got = np.asarray(
            jax.lax.bitcast_convert_type(got_bits, jnp.float32)
        )
        want = ref.pim_add_ref(ref.pim_mul_ref(a, b), c)
        assert_bits_equal(got, want, f"mac seed={seed}:")
