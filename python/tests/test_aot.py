"""AOT artifact contract: every spec lowers to parseable HLO text with the
expected entry computation signature."""

import re

import jax
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def specs():
    return aot.artifact_specs()


def test_spec_inventory(specs):
    assert set(specs) == {
        "lenet_train_step",
        "lenet_eval",
        "lenet_init",
        "pim_fp32_mul",
        "pim_fp32_add",
    }


@pytest.mark.parametrize(
    "name,n_args,n_outs",
    [
        ("lenet_train_step", 11, 9),
        ("lenet_eval", 10, 2),
        ("lenet_init", 1, 8),
        ("pim_fp32_mul", 2, 1),
        ("pim_fp32_add", 2, 1),
    ],
)
def test_lowering_signature(specs, name, n_args, n_outs):
    fn, example_args, _doc = specs[name]
    assert len(example_args) == n_args
    lowered = jax.jit(fn).lower(*example_args)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:60]
    # Extract the ENTRY computation body (this dump style puts no signature
    # on the ENTRY line) and count parameter instructions + ROOT tuple arity.
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    body = []
    for l in lines[start + 1 :]:
        if l.strip() == "}":
            break
        body.append(l)
    params = [l for l in body if re.search(r"= \S+ parameter\(\d+\)", l)]
    assert len(params) == n_args, f"{name}: {len(params)} parameters"
    root = next(l for l in body if l.strip().startswith("ROOT"))
    m = re.search(r"tuple\((?P<elems>.*)\)", root)
    assert m, root
    elems = [e for e in m.group("elems").split(", ") if e]
    assert len(elems) == n_outs, root


def test_no_custom_calls(specs):
    """interpret=True pallas must lower to plain HLO the CPU client can run."""
    for name, (fn, example_args, _doc) in specs.items():
        text = aot.to_hlo_text(jax.jit(fn).lower(*example_args))
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_train_batch_shape_in_text(specs):
    fn, example_args, _ = specs["lenet_train_step"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*example_args))
    assert f"f32[{model.TRAIN_BATCH},1,28,28]" in text
