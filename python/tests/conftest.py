"""Shared fixtures + hypothesis profile for the kernel/model suites."""

import numpy as np
import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "kernels",
    deadline=None,  # interpret-mode pallas is slow; wallclock is meaningless
    max_examples=25,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("kernels")


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)


def assert_bits_equal(got, want, msg=""):
    """Exact fp32 bit equality, treating any-NaN == any-NaN."""
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    gb, wb = got.view(np.uint32), want.view(np.uint32)
    ok = (gb == wb) | (np.isnan(got) & np.isnan(want))
    if not ok.all():
        i = int(np.argmax(~ok))
        raise AssertionError(
            f"{msg} bit mismatch at {i}: got {got.flat[i]!r} ({gb.flat[i]:#010x}) "
            f"want {want.flat[i]!r} ({wb.flat[i]:#010x})"
        )
