#!/usr/bin/env python3
"""Pre-validation of the PR 9 serving tier: coalescing policy, admission
control, deadline shedding and percentile accounting — mirrored
loop-for-loop from `rust/src/serve/sim.rs` (no Rust toolchain in the
authoring container, so the discrete-event semantics are proven here
first and the Rust implementation transcribes them).

What is validated:

 1. Determinism: the same seed replays the identical event sequence.
 2. Conservation: submitted == admitted + rejected and
    admitted == completed + shed + failed, over a randomized grid of
    policies, loads and fault configurations.
 3. Front-only deadline shedding == full-queue-scan shedding: the queue
    is FIFO and every request carries the same deadline offset, so the
    front request always has the earliest expiry — shedding only from
    the front is exact, not an approximation.
 4. Nearest-rank percentile accounting against a brute-force reference.
 5. The admitted-p99 bound the bench gates in-binary:
    p99 <= deadline + 2*svc(max_batch) + max_wait whenever a deadline
    is armed (one transient-redispatch service slot of slack).
 6. Batching never exceeds max_batch and never dispatches empty.

Run with --emit-baseline to print the scenario table the committed
`BENCH_serving.json` / EXPERIMENTS.md values are derived from (count
metrics are exact mirrors; millisecond metrics scale linearly with the
analytic t_mac and are guarded by ceiling gates with slack, not
equality gates).
"""

import math
import sys

MASK = (1 << 64) - 1

# ---------------------------------------------------------------------------
# Mirrors of rust/src/prop/mod.rs (xorshift64*) and sim/faults.rs
# (splitmix64 fault draws).


class Rng:
    """xorshift64* — mirror of prop::Rng."""

    def __init__(self, seed):
        self.s = max(seed, 1) & MASK

    def next_u64(self):
        x = self.s
        x ^= (x << 13) & MASK
        x ^= x >> 7
        x ^= (x << 17) & MASK
        self.s = x
        return (x * 0x2545F4914F6CDD1D) & MASK

    def unit_f64(self):
        return (self.next_u64() >> 11) / float(1 << 53)


def mix64(z):
    z = (z + 0x9E3779B97F4A7C15) & MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
    return z ^ (z >> 31)


def fault_hash(seed, salt, a, b, c):
    h = mix64(seed ^ salt)
    h = mix64(h ^ a)
    h = mix64(h ^ b)
    return mix64(h ^ c)


def unit(h):
    return (h >> 11) * (1.0 / float(1 << 53))


CHIP_FAIL_SALT = 0x434849504641494C  # "CHIPFAIL"
CHIP_DEAD_SALT = 0x4348495044454144  # "CHIPDEAD"


def chip_is_dead(seed, chip_dead, chip, chips):
    k = min(chip_dead, chips)
    if k == 0 or chip == 0 or chip > chips:
        return False
    hc = fault_hash(seed, CHIP_DEAD_SALT, chip, 0, 0)
    rank = 0
    for c in range(1, chips + 1):
        if c == chip:
            continue
        h = fault_hash(seed, CHIP_DEAD_SALT, c, 0, 0)
        if h < hc or (h == hc and c < chip):
            rank += 1
    return rank < k


def chip_failed_transiently(seed, chip_fail, chip, step):
    return chip_fail > 0.0 and unit(fault_hash(seed, CHIP_FAIL_SALT, step, chip, 0)) < chip_fail


# ---------------------------------------------------------------------------
# Mirror of the analytic service-time model: fpu/cost.rs t_mac() over
# nvsim OpCosts::proposed_default() (1024x1024 array, OneT1R cell,
# SOT_MRAM_TABLE1, 28 nm node), and the per-layer GEMM wave pricing of
# arch/gemm.rs (waves = ceil(macs / lanes), latency = waves * t_mac).

LANES = 32_768  # runtime::FUNCTIONAL_LANES


def t_mac_fp32():
    pitch = math.sqrt(30.0) * 28e-9  # OneT1R cell_area_f2=30 @ 28 nm
    line = 1024 * pitch
    c_line = 200e-12 * line
    r_line = 2.0e6 * line
    t_read = 0.25e-9 + 0.5 * r_line * c_line + 0.40e-9  # decode + elmore + sense
    t_search = t_read
    t_write = (0.28e-9 + 2.0e-9) * 1  # (driver + switch) * write_steps
    ne, nm = 8, 23
    t_mul = (2.0 * nm * nm + 6.5 * nm + 6.0 * ne + 3.0) * (t_read + t_write)
    t_add = (
        (1.0 + 7.0 * ne + 7.0 * nm) * t_read
        + (7.0 * ne + 7.0 * nm) * t_write
        + 2.0 * (nm + 2.0) * t_search
    )
    return t_mul + t_add


T_MAC = t_mac_fp32()

# LeNet-5 GEMM layers as (per-sample macs, output rows per sample, cols):
#   conv1: m = b*576, n = 6,  k = 25   -> 86_400 macs/sample
#   conv2: m = b*64,  n = 12, k = 150  -> 115_200
#   dense1: m = b,    n = 97, k = 192  -> 18_624
#   dense2: m = b,    n = 10, k = 97   -> 970
LENET_GEMMS = [(86_400, 576, 6), (115_200, 64, 12), (18_624, 1, 97), (970, 1, 10)]


def svc_latency(batch):
    """Clean forward latency of one batched dispatch.  Accumulated
    per layer — `t += waves_l * t_mac` — because ForwardResult.latency_s
    sums each GEMM layer's priced latency in layer order, which is not
    bit-identical to `(sum of waves) * t_mac` in f64."""
    t = 0.0
    for macs, _, _ in LENET_GEMMS:
        waves = (batch * macs + LANES - 1) // LANES
        t += waves * T_MAC
    return t


def abft_latency(batch):
    """ABFT checksum pricing of an armed, fault-free forward: the
    reference+verify adds (2*m*n per GEMM) summed over the pass, then
    ceil-divided by the lanes once — the train_step pricing idiom."""
    adds = sum(2 * (batch * rows_per) * cols for _, rows_per, cols in LENET_GEMMS)
    return ((adds + LANES - 1) // LANES) * T_MAC


# ---------------------------------------------------------------------------
# The serving policy + discrete-event loop (mirror of serve/sim.rs).

DEF_MAX_BATCH = 32
DEF_MAX_WAIT = 2e-3
DEF_DEPTH = 256
DEF_DEADLINE = 8e-3


def open_loop_arrivals(n, rate, seed):
    rng = Rng(seed)
    t = 0.0
    out = []
    for _ in range(n):
        u = rng.unit_f64()
        t += -math.log(1.0 - u) / rate
        out.append(t)
    return out


def percentile(samples, q):
    """Nearest-rank percentile (mirror of serve/metrics.rs)."""
    if not samples:
        return 0.0
    s = sorted(samples)
    rank = math.ceil(q / 100.0 * len(s))
    return s[max(rank, 1) - 1]


def simulate(
    arrivals,
    chips=2,
    max_batch=DEF_MAX_BATCH,
    max_wait=DEF_MAX_WAIT,
    depth=DEF_DEPTH,
    deadline=DEF_DEADLINE,
    armed=False,
    fault_seed=1,
    chip_dead=0,
    chip_fail=0.0,
    shed_full_scan=False,
):
    """The serve/sim.rs event loop, op-for-op.  Returns the stats dict.

    `shed_full_scan=True` switches deadline shedding from front-only to
    a full queue scan — used by check 3 to prove the two are identical
    under FIFO + uniform deadlines."""
    INF = float("inf")
    live = [c for c in range(1, chips + 1) if not (armed and chip_is_dead(fault_seed, chip_dead, c, chips))]
    if not live:
        raise RuntimeError("all chips dead")
    free_at = {c: 0.0 for c in live}
    queue = []  # request indices (FIFO)
    lat = []
    st = dict(
        submitted=0, admitted=0, rejected=0, shed=0, completed=0, failed=0,
        batches=0, batched_samples=0, redispatched=0, fault_latency=0.0,
    )
    n = len(arrivals)
    i = 0
    now = 0.0
    step = 0
    last_done = 0.0
    max_seen_batch = 0

    def admit(j):
        st["submitted"] += 1
        if len(queue) >= depth:
            st["rejected"] += 1
        else:
            queue.append(j)
            st["admitted"] += 1

    while True:
        drained = i >= n
        if not queue:
            if drained:
                break
            now = max(now, arrivals[i])
            admit(i)
            i += 1
            continue
        t_chip = min(free_at[c] for c in live)
        front = arrivals[queue[0]]
        t_ready = now if (len(queue) >= max_batch or drained) else front + max_wait
        t_disp = max(now, t_chip, t_ready)
        if not drained and arrivals[i] <= t_disp:
            now = max(now, arrivals[i])
            admit(i)
            i += 1
            continue
        now = t_disp
        # --- dispatch at `now` ---
        if deadline > 0.0:
            if shed_full_scan:
                kept = [j for j in queue if not now - arrivals[j] > deadline]
                st["shed"] += len(queue) - len(kept)
                queue[:] = kept
            else:
                while queue and now - arrivals[queue[0]] > deadline:
                    queue.pop(0)
                    st["shed"] += 1
        if not queue:
            continue
        b = min(len(queue), max_batch)
        ids = queue[:b]
        del queue[:b]
        max_seen_batch = max(max_seen_batch, b)
        # earliest-free live chip (lowest id wins ties)
        chip = live[0]
        for c in live[1:]:
            if free_at[c] < free_at[chip]:
                chip = c
        start = now
        this_step = step
        step += 1
        if armed and chip_failed_transiently(fault_seed, chip_fail, chip, this_step):
            free_at[chip] = start + svc_latency(b)
            st["redispatched"] += 1
            chip = live[0]
            for c in live[1:]:
                if free_at[c] < free_at[chip]:
                    chip = c
            start = max(now, free_at[chip])
        fault_extra = abft_latency(b) if armed else 0.0
        latency = svc_latency(b) + fault_extra
        done = start + latency
        free_at[chip] = done
        last_done = max(last_done, done)
        st["batches"] += 1
        st["batched_samples"] += b
        st["fault_latency"] += fault_extra
        # fault-free mirror: unrecovered is always 0 here, so every
        # dispatched batch completes
        st["completed"] += b
        for j in ids:
            lat.append(done - arrivals[j])

    elapsed = max(now, last_done)
    st["elapsed"] = elapsed
    st["p50"] = percentile(lat, 50.0)
    st["p99"] = percentile(lat, 99.0)
    st["mean"] = sum(lat) / len(lat) if lat else 0.0
    st["throughput"] = st["completed"] / elapsed if elapsed > 0.0 else 0.0
    st["max_seen_batch"] = max_seen_batch
    return st


def capacity_rps(chips, max_batch):
    return chips * max_batch / svc_latency(max_batch)


# ---------------------------------------------------------------------------
# Checks.


def check_determinism():
    arr = open_loop_arrivals(4000, 1.2 * capacity_rps(2, 32), 42)
    a = simulate(arr)
    b = simulate(arr)
    assert a == b, "same inputs must replay identically"
    print("determinism: OK")


def check_conservation():
    rng = Rng(7)
    cases = 0
    for _ in range(200):
        chips = 1 + rng.next_u64() % 3
        max_batch = 1 + rng.next_u64() % 32
        depth = 1 + rng.next_u64() % 64
        max_wait = rng.unit_f64() * 4e-3
        deadline = 0.0 if rng.next_u64() % 4 == 0 else rng.unit_f64() * 12e-3
        mult = 0.25 + rng.unit_f64() * 3.0
        chip_fail = 0.0 if rng.next_u64() % 2 == 0 else rng.unit_f64() * 0.5
        chip_dead = rng.next_u64() % chips  # always leaves a survivor
        armed = chip_fail > 0.0 or chip_dead > 0
        n = 200 + rng.next_u64() % 400
        arr = open_loop_arrivals(int(n), mult * capacity_rps(chips, max_batch), rng.next_u64())
        st = simulate(
            arr, chips=int(chips), max_batch=int(max_batch), depth=int(depth),
            max_wait=max_wait, deadline=deadline, armed=armed,
            fault_seed=rng.next_u64() | 1, chip_dead=int(chip_dead), chip_fail=chip_fail,
        )
        assert st["submitted"] == len(arr)
        assert st["submitted"] == st["admitted"] + st["rejected"], st
        assert st["admitted"] == st["completed"] + st["shed"] + st["failed"], st
        assert st["batched_samples"] == st["completed"] + st["failed"]
        assert st["max_seen_batch"] <= max_batch
        cases += 1
    print(f"conservation over {cases} randomized configs: OK")


def check_front_only_shed():
    rng = Rng(13)
    for _ in range(60):
        chips = 1 + rng.next_u64() % 2
        max_batch = 1 + rng.next_u64() % 16
        deadline = 1e-4 + rng.unit_f64() * 3e-3  # tight: force shedding
        mult = 1.0 + rng.unit_f64() * 3.0
        arr = open_loop_arrivals(400, mult * capacity_rps(int(chips), int(max_batch)), rng.next_u64())
        a = simulate(arr, chips=int(chips), max_batch=int(max_batch), deadline=deadline)
        b = simulate(arr, chips=int(chips), max_batch=int(max_batch), deadline=deadline,
                     shed_full_scan=True)
        assert a == b, f"front-only shed diverged from full scan: {a} vs {b}"
    print("front-only shed == full-queue-scan shed (FIFO + uniform deadline): OK")


def check_percentiles():
    rng = Rng(5)
    for _ in range(100):
        n = 1 + rng.next_u64() % 200
        samples = [rng.unit_f64() for _ in range(n)]
        for q in (50.0, 90.0, 99.0, 100.0):
            got = percentile(samples, q)
            # brute-force nearest-rank: smallest x with rank(x) >= q% of n
            s = sorted(samples)
            k = max(math.ceil(q / 100.0 * len(s)), 1)
            assert got == s[k - 1]
            # at least q% of samples are <= the reported percentile
            assert sum(1 for x in samples if x <= got) >= q / 100.0 * len(s) - 1e-9
    assert percentile([], 99.0) == 0.0
    print("nearest-rank percentile accounting: OK")


def check_p99_bound():
    rng = Rng(23)
    for _ in range(40):
        mult = 0.5 + rng.unit_f64() * 2.5
        chip_fail = 0.0 if rng.next_u64() % 2 == 0 else 0.3
        arr = open_loop_arrivals(2000, mult * capacity_rps(2, 32), rng.next_u64())
        st = simulate(arr, armed=chip_fail > 0.0, chip_fail=chip_fail,
                      fault_seed=rng.next_u64() | 1)
        bound = DEF_DEADLINE + 2.0 * svc_latency(DEF_MAX_BATCH) + DEF_MAX_WAIT
        assert st["p99"] <= bound, f"p99 {st['p99'] * 1e3:.3f} ms over bound {bound * 1e3:.3f} ms"
    print("admitted-p99 bound (deadline + 2*svc(B) + max_wait): OK")


# ---------------------------------------------------------------------------
# Baseline scenarios (the committed BENCH_serving.json values).

WALL_MS_PER_BATCH = 29.0  # committed lenet5 forward batch-32 wall (threads 4)


def scenario_table():
    cap = capacity_rps(2, DEF_MAX_BATCH)
    rows = []
    for name, n, mult, dead in [
        ("1.0x healthy", 100_000, 1.0, False),
        ("2.0x healthy", 20_000, 2.0, False),
        ("0.5x healthy", 20_000, 0.5, False),
        ("1.0x-of-healthy, one chip dead", 20_000, 1.0, True),
    ]:
        arr = open_loop_arrivals(n, mult * cap, 42)
        st = simulate(arr, armed=dead, chip_dead=1 if dead else 0, fault_seed=9)
        st["name"], st["n"], st["mult"] = name, n, mult
        rows.append(st)
    return cap, rows


def emit_baseline():
    cap, rows = scenario_table()
    print(f"t_mac = {T_MAC * 1e6:.6f} us   svc(32) = {svc_latency(32) * 1e3:.6f} ms   "
          f"svc(1) = {svc_latency(1) * 1e6:.3f} us")
    print(f"healthy capacity (2 chips) = {cap:,.1f} req/s\n")
    hdr = (f"{'scenario':<34} {'thr krps':>9} {'p50 ms':>8} {'p99 ms':>8} "
           f"{'rej %':>7} {'shed %':>7} {'batches':>8} {'wall est s':>10}")
    print(hdr)
    for st in rows:
        rej = 100.0 * st["rejected"] / st["submitted"]
        shed = 100.0 * st["shed"] / st["submitted"]
        wall = st["batches"] * WALL_MS_PER_BATCH / 1e3
        print(f"{st['name']:<34} {st['throughput'] / 1e3:>9.2f} {st['p50'] * 1e3:>8.3f} "
              f"{st['p99'] * 1e3:>8.3f} {rej:>7.2f} {shed:>7.2f} {st['batches']:>8} {wall:>10.1f}")
    print("\nBENCH_serving.json metric values (mean_ns carries the metric):")
    s1, s2, _s05, sd = rows[0], rows[1], rows[2], rows[3]
    print(f"  throughput krps @1.0x healthy      = {s1['throughput'] / 1e3:.1f}")
    print(f"  p50 ms @1.0x healthy               = {s1['p50'] * 1e3:.1f}")
    print(f"  p99 ms @1.0x healthy               = {s1['p99'] * 1e3:.1f}")
    print(f"  p99 ms @2.0x healthy               = {s2['p99'] * 1e3:.1f}")
    print(f"  shed+reject pct @2.0x healthy      = "
          f"{100.0 * (s2['shed'] + s2['rejected']) / s2['submitted']:.1f}")
    print(f"  p99 ms @1.0x one-dead              = {sd['p99'] * 1e3:.1f}")
    print(f"  completed pct @1.0x one-dead       = {100.0 * sd['completed'] / sd['submitted']:.1f}")


def main():
    check_determinism()
    check_conservation()
    check_front_only_shed()
    check_percentiles()
    check_p99_bound()
    print("\nvalidate_serving_batching: ALL CHECKS PASSED")
    if "--emit-baseline" in sys.argv:
        print()
        emit_baseline()
    return 0


if __name__ == "__main__":
    sys.exit(main())
