"""Pallas-backed conv2d / avg_pool2 vs XLA's own convolution."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.conv2d import avg_pool2, conv2d, im2col
from compile.kernels.ref import avg_pool2_ref, conv2d_ref


def _rand(rng, shape):
    return rng.standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize(
    "b,c,h,w,o,kh,kw",
    [
        (1, 1, 5, 5, 1, 5, 5),      # degenerate 1x1 output
        (2, 1, 28, 28, 6, 5, 5),    # LeNet conv1
        (2, 6, 12, 12, 12, 5, 5),   # LeNet conv2
        (3, 4, 9, 11, 7, 3, 3),     # asymmetric
        (1, 2, 8, 8, 3, 1, 1),      # pointwise
    ],
)
def test_conv_shapes(rng, b, c, h, w, o, kh, kw):
    x = _rand(rng, (b, c, h, w))
    wgt = _rand(rng, (o, c, kh, kw))
    bias = _rand(rng, (o,))
    got = np.asarray(conv2d(x, wgt, bias))
    want = np.asarray(conv2d_ref(x, wgt, bias))
    assert got.shape == (b, o, h - kh + 1, w - kw + 1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    b=st.integers(1, 3),
    c=st.integers(1, 4),
    o=st.integers(1, 6),
    k=st.sampled_from([1, 3, 5]),
    extra=st.integers(0, 6),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15)
def test_hypothesis_conv_sweep(b, c, o, k, extra, seed):
    rng = np.random.default_rng(seed)
    h = w = k + extra
    x = _rand(rng, (b, c, h, w))
    wgt = _rand(rng, (o, c, k, k))
    got = np.asarray(conv2d(x, wgt))
    want = np.asarray(conv2d_ref(x, wgt))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_im2col_column_order(rng):
    """Column ordering must match OIHW weight reshape (C, KH, KW)."""
    x = _rand(rng, (1, 2, 4, 4))
    cols, (b, oh, ow) = im2col(x, 3, 3)
    assert cols.shape == (1 * 2 * 2, 2 * 9)
    # patch at output (0,0): x[0, :, 0:3, 0:3] flattened C-major
    want = np.asarray(x)[0, :, 0:3, 0:3].reshape(-1)
    np.testing.assert_array_equal(np.asarray(cols)[0], want)


def test_avg_pool(rng):
    x = _rand(rng, (2, 3, 8, 10))
    np.testing.assert_allclose(
        np.asarray(avg_pool2(x)), np.asarray(avg_pool2_ref(x)), rtol=1e-5, atol=1e-7
    )


def test_conv_grad_matches_xla(rng):
    x = _rand(rng, (2, 1, 10, 10))
    wgt = _rand(rng, (3, 1, 5, 5))
    bias = _rand(rng, (3,))

    ours = lambda w, b: jnp.sum(conv2d(x, w, b) ** 2)
    ref = lambda w, b: jnp.sum(conv2d_ref(x, w, b) ** 2)
    gw1, gb1 = jax.grad(ours, argnums=(0, 1))(wgt, bias)
    gw2, gb2 = jax.grad(ref, argnums=(0, 1))(wgt, bias)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb1), np.asarray(gb2), rtol=1e-4, atol=1e-4)


def test_conv_input_grad(rng):
    x = _rand(rng, (1, 2, 9, 9))
    wgt = _rand(rng, (4, 2, 3, 3))
    ours = lambda x: jnp.sum(jnp.sin(conv2d(x, wgt)))
    ref = lambda x: jnp.sum(jnp.sin(conv2d_ref(x, wgt)))
    np.testing.assert_allclose(
        np.asarray(jax.grad(ours)(x)),
        np.asarray(jax.grad(ref)(x)),
        rtol=1e-4,
        atol=1e-4,
    )
