"""Validation harness for the PR 8 resident decoded-weight panels.

With the decoded u64 panel as the *resident* weight format, the SGD
update and the weight-storage fault model must operate in the decoded
domain directly — encode back to f32 only at checkpoint/eval/all-reduce
boundaries.  This script ports the bit-exact PIM softfloat reference
(rust/src/fpu/softfloat.rs) to Python and proves three things:

1. ``pim_sgd_dec(wdec, lr, g)`` — the decoded-domain update
   ``decode(add(encode(wdec), mul(lr, g) ^ SIGN))`` — is bit-identical
   to the frozen f32 chain ``pim_sub_f32(w, pim_mul_f32(lr, g))`` on
   every edge-grid triple and a large random sweep, and its result is
   *canonical* (``decode(encode(d)) == d``), so the resident panel can
   feed ``pim_mac_acc_dec`` forever without re-normalisation.

2. The dec-native fault injectors ``frac_flip_dec``/``frac_force_dec``
   (XOR / force a significand bit of the resident word, mirror kept via
   ``pim_encode``) are bit-identical to the f32-path ``frac_flip``/
   ``frac_force`` (which wrap the same bit op in decode/encode), for
   every bit 0..=22 the fault model draws, on every pattern class —
   and also preserve canonicality.

3. ``pim_sub_dec(adec, bbits)`` — decoded-domain subtract used by the
   update — matches ``pim_sub_f32`` on the full edge grid.

Run: python3 python/tests/validate_resident_sgd.py
(Repo convention: the authoring container has no Rust toolchain, so the
numerics are pre-validated here; the Rust tests
`fpu::softfloat::tests::sgd_dec_matches_f32_chain_on_triple_grid` and
`sim::faults::tests::corrupt_weights_dec_matches_f32_path` re-check the
same grids on every `cargo test`.)
"""

QNAN = 0x7FC00000
INF = 0x7F800000
EXP = 0x7F800000
MIN_NORMAL_MANT = 0x00800000
M32 = 0xFFFFFFFF
SIGN = 0x80000000


def fields(bits):
    return (bits >> 31) & 1, (bits >> 23) & 0xFF, bits & 0x7FFFFF


def mul_core_sig(sign, ea, ma, eb, mb):
    p = ma * mb
    top_set = (p >> 47) & 1
    s = 23 + top_set
    mant_preround = (p >> s) & 0xFFFFFF
    guard = (p >> (s - 1)) & 1
    sticky = (p & ((1 << (s - 1)) - 1)) != 0
    round_up = guard == 1 and (sticky or (mant_preround & 1) == 1)
    mant = mant_preround + (1 if round_up else 0)
    e = ea + eb - 127 + top_set
    e0 = e
    if mant == 1 << 24:
        mant >>= 1
        e += 1
    if e >= 255:
        return sign | INF
    if e <= 0:
        if e0 == 0 and mant_preround == 0xFFFFFF:
            return sign | MIN_NORMAL_MANT
        return sign
    return sign | (e << 23) | (mant & 0x7FFFFF)


def pim_mul_bits(abits, bbits):
    sa, ea, fa = fields(abits)
    sb, eb, fb = fields(bbits)
    a_nan = ea == 255 and fa != 0
    b_nan = eb == 255 and fb != 0
    a_inf = ea == 255 and fa == 0
    b_inf = eb == 255 and fb == 0
    a_zero = ea == 0
    b_zero = eb == 0
    sign = ((sa ^ sb) << 31) & M32
    if a_nan or b_nan or (a_inf and b_zero) or (b_inf and a_zero):
        return QNAN
    if a_inf or b_inf:
        return sign | INF
    if a_zero or b_zero:
        return sign
    return mul_core_sig(sign, ea, fa | MIN_NORMAL_MANT, eb, fb | MIN_NORMAL_MANT)


def pim_add_bits(abits, bbits):
    sa, ea, fa = fields(abits)
    sb, eb, fb = fields(bbits)
    a_nan = ea == 255 and fa != 0
    b_nan = eb == 255 and fb != 0
    a_inf = ea == 255 and fa == 0
    b_inf = eb == 255 and fb == 0
    a_zero = ea == 0
    b_zero = eb == 0
    if a_nan or b_nan or (a_inf and b_inf and sa != sb):
        return QNAN
    if a_inf:
        return abits
    if b_inf:
        return bbits
    if a_zero and b_zero:
        return ((sa & sb) << 31) & M32
    if a_zero:
        return bbits
    if b_zero:
        return abits

    if (abits & 0x7FFFFFFF) >= (bbits & 0x7FFFFFFF):
        xbits, ybits = abits, bbits
    else:
        xbits, ybits = bbits, abits
    sx, ex, fx = fields(xbits)
    _, ey, fy = fields(ybits)
    mx = (fx | MIN_NORMAL_MANT) << 3
    my = (fy | MIN_NORMAL_MANT) << 3
    d = min(ex - ey, 27)
    lost = my & ((1 << d) - 1)
    my_al = (my >> d) | (1 if lost != 0 else 0)
    subtract = sx != (ybits >> 31) & 1
    total = (mx - my_al) if subtract else (mx + my_al)
    if total == 0:
        return 0
    p = total.bit_length() - 1
    if p == 27:
        total_n, e0 = (total >> 1) | (total & 1), ex + 1
    else:
        total_n, e0 = total << (26 - p), ex - (26 - p)
    kept_preround = total_n >> 3
    rb = (total_n >> 2) & 1
    st = (total_n & 3) != 0
    round_up = rb == 1 and (st or (kept_preround & 1) == 1)
    kept = kept_preround + (1 if round_up else 0)
    e = e0
    if kept == 1 << 24:
        kept >>= 1
        e += 1
    sign = (sx << 31) & M32
    if e >= 255:
        return sign | INF
    if e <= 0:
        if e0 == 0 and kept_preround == 0xFFFFFF:
            return sign | MIN_NORMAL_MANT
        return sign
    return sign | (e << 23) | (kept & 0x7FFFFF)


def pim_decode(bits):
    e = (bits >> 23) & 0xFF
    f = bits & 0x7FFFFF
    mant = (f | MIN_NORMAL_MANT) if 1 <= e <= 254 else f
    return mant | (e << 24) | (((bits >> 31) & 1) << 32)


def pim_encode(dec):
    return ((((dec >> 32) & 1) << 31) | (((dec >> 24) & 0xFF) << 23) | (dec & 0x7FFFFF)) & M32


def pim_sub_f32(abits, bbits):
    """Frozen engine update primitive: a - b as add(a, -b)."""
    return pim_add_bits(abits, bbits ^ SIGN)


# ---- PR 8 decoded-domain primitives (mirrors of the new Rust) ----

def pim_sub_dec(adec, bbits):
    return pim_decode(pim_add_bits(pim_encode(adec), bbits ^ SIGN))


def pim_sgd_dec(wdec, lrbits, gbits):
    return pim_sub_dec(wdec, pim_mul_bits(lrbits, gbits))


# ---- fault model: f32-path (frozen) vs dec-native (PR 8) ----

def frac_flip(bits, bit):
    return pim_encode(pim_decode(bits) ^ (1 << bit))


def frac_force(bits, bit, one):
    dec = pim_decode(bits)
    mask = 1 << bit
    dec = (dec | mask) if one else (dec & ~mask)
    return pim_encode(dec)


def frac_flip_dec(dec, bit):
    return dec ^ (1 << bit)


def frac_force_dec(dec, bit, one):
    mask = 1 << bit
    return (dec | mask) if one else (dec & ~mask)


def edge_bit_patterns():
    exps = [0, 1, 2, 127, 253, 254, 255]
    mants = [0, 1, 0x400000, 0x7FFFFF]
    out = []
    for e in exps:
        for m in mants:
            for s in (0, 1):
                out.append(((s << 31) | (e << 23) | m) & M32)
    return out


def canonical(dec):
    return pim_decode(pim_encode(dec)) == dec


def main():
    grid = edge_bit_patterns()

    # 1. decoded-domain SGD == frozen f32 chain, on the full triple grid
    n = 0
    for w in grid:
        wdec = pim_decode(w)
        assert canonical(wdec)
        for lr in grid:
            for g in grid:
                got_dec = pim_sgd_dec(wdec, lr, g)
                want = pim_sub_f32(w, pim_mul_bits(lr, g))
                assert pim_encode(got_dec) == want, (
                    f"sgd mismatch w={w:#010x} lr={lr:#010x} g={g:#010x}: "
                    f"enc(dec)={pim_encode(got_dec):#010x} f32={want:#010x}"
                )
                assert canonical(got_dec), f"non-canonical sgd result {got_dec:#x}"
                n += 1
    print(f"sgd edge-grid triples OK: {n}")

    # also pim_sub_dec alone on the pair grid
    for a in grid:
        adec = pim_decode(a)
        for b in grid:
            assert pim_encode(pim_sub_dec(adec, b)) == pim_sub_f32(a, b)
    print(f"sub edge-grid pairs OK: {len(grid) ** 2}")

    # 2. dec-native fault injectors == f32-path, all bits 0..=22, all classes
    checked = 0
    for w in grid:
        dec = pim_decode(w)
        for bit in range(23):
            nf = frac_flip_dec(dec, bit)
            assert pim_encode(nf) == frac_flip(w, bit), (
                f"flip mismatch w={w:#010x} bit={bit}"
            )
            assert canonical(nf), f"non-canonical flip {nf:#x} (w={w:#010x} bit={bit})"
            for one in (False, True):
                ns = frac_force_dec(dec, bit, one)
                assert pim_encode(ns) == frac_force(w, bit, one), (
                    f"force mismatch w={w:#010x} bit={bit} one={one}"
                )
                assert canonical(ns)
                checked += 3
    print(f"fault-injector patterns OK: {checked}")

    # 3. random sweep: SGD chain + chained updates stay canonical and in
    #    lockstep with the f32 mirror across multiple steps (the resident
    #    lifetime: decode once, update in place many times)
    state = 0xC0FFEE5EED5EED01
    def rnd():
        nonlocal state
        state ^= (state << 13) & 0xFFFFFFFFFFFFFFFF
        state ^= state >> 7
        state ^= (state << 17) & 0xFFFFFFFFFFFFFFFF
        return state

    for trial in range(50_000):
        w = rnd() & M32
        wdec = pim_decode(w)
        # 4 chained updates interleaved with fault hits — the resident life
        for step in range(4):
            lr = rnd() & M32
            g = rnd() & M32
            if step % 2 == 0:
                g &= 0x807FFFFF  # zero-class gradient half the time
            wdec = pim_sgd_dec(wdec, lr, g)
            w = pim_sub_f32(w, pim_mul_bits(lr, g))
            assert pim_encode(wdec) == w, f"trial {trial} step {step} drifted"
            assert canonical(wdec)
            h = rnd()
            bit = h % 23
            if h & 1:
                wdec = frac_flip_dec(wdec, bit)
                w = frac_flip(w, bit)
            else:
                wdec = frac_force_dec(wdec, bit, (h >> 8) & 1 == 1)
                w = frac_force(w, bit, (h >> 8) & 1 == 1)
            assert pim_encode(wdec) == w, f"trial {trial} fault step {step} drifted"
            assert canonical(wdec)
    print("random chained resident updates OK: 50000 trials x 4 steps")
    print("resident decoded-domain SGD + fault injection are bit-identical")


if __name__ == "__main__":
    main()
