"""Pre-validation for the PR 7 per-shard batched gradient accumulation.

The cluster used to reduce one microgradient per *sample* (32 host
lowerings per step — the shards=2 wall-clock anomaly).  The fix runs one
batched backward per shard, which regroups the canonical
global-sample-order `pim_add` chain.  FTZ fp32 addition is NOT
associative, so the regrouping has to be chosen carefully:

  * naive: each shard folds its chunk from +0 into an independent
    partial, then the host folds the S partials.  This is a DIFFERENT
    grouping of the same terms and is **not** bit-identical to the
    global chain (counterexample below, plus a random census).
  * seeded chain continuation: shard s's accumulation *starts from* the
    merged partial of shards 0..s-1.  The concatenated per-chunk chains
    are then literally the global chain, paused at chunk boundaries —
    bit-identical by construction, for any split, including empty
    chunks.  This is what the Rust `gemm_tn` seed + seeded db fold
    implement.

This script proves both halves on the exact softfloat semantics
(imported from validate_decoded_mac.py, the PR 5 harness that mirrors
rust/src/fpu/softfloat.rs branch for branch), over:

  - dense wgrad row order (row b = sample b), and
  - conv wgrad row order (row r = b*ohw + p, sample-major — chunking at
    sample boundaries keeps row ranges contiguous),

for shard counts {1, 2, 4, 8, 16, 32, 64} of a batch of 32 (shards=64
exercises zero-sample chunks, which must pass the carry through
untouched).  It also pre-validates the cluster_scaling in-binary gate
arithmetic: with the paper's cost constants, shards=64 simulated step
latency is < 0.05x shards=1 for LeNet-5 at batch 32 / 32,768 lanes.

Run: python3 python/tests/validate_shard_reduce.py
(Repo convention: the authoring container has no Rust toolchain, so the
numerics are pre-validated here; the Rust property test
`cluster::prop_shard_chain_matches_engine` re-checks the same
regrouping on every `cargo test`.)
"""

import math
import random
import struct

from validate_decoded_mac import pim_add_bits, pim_mac_acc_bits

M32 = 0xFFFFFFFF


def f2b(x):
    return struct.unpack("<I", struct.pack("<f", x))[0]


def shard_split(batch, shards):
    """Mirror of ShardPlan::split after the PR 7 relaxation: front-load
    the remainder; shards beyond the batch get empty (lo == hi) chunks."""
    assert shards >= 1 and batch >= 1
    base, rem = divmod(batch, shards)
    chunks, start = [], 0
    for t in range(shards):
        take = base + (1 if t < rem else 0)
        chunks.append((start, start + take))
        start += take
    assert start == batch
    return chunks


def chain_wgrad(rows, row_terms):
    """Global chain: acc_{r+1} = pim_add(acc_r, ftz(d_r * x_r)) from +0,
    rows in ascending order — the canonical single-chip contraction."""
    acc = 0
    for r in rows:
        d, x = row_terms[r]
        acc = pim_mac_acc_bits(acc, d, x)
    return acc


def naive_shard_fold(chunks, row_terms):
    """Independent per-chunk partials from +0, folded left — NOT the
    canonical chain (each chunk re-rounds from zero)."""
    acc = 0
    for lo, hi in chunks:
        part = chain_wgrad(range(lo, hi), row_terms)
        acc = pim_add_bits(acc, part)
    return acc


def seeded_shard_chain(chunks, row_terms):
    """Chain continuation: chunk s starts from the carry of chunks
    0..s-1 — what the seeded gemm_tn / seeded db fold compute."""
    carry = 0
    for lo, hi in chunks:
        for r in range(lo, hi):
            d, x = row_terms[r]
            carry = pim_mac_acc_bits(carry, d, x)
    return carry


def random_bits(rng):
    # Wide exponent spread so alignment shifts + cancellation are common:
    # that is where FTZ non-associativity bites.
    e = rng.choice([0, 1, 20, 96, 126, 127, 128, 158, 230, 254])
    m = rng.getrandbits(23)
    s = rng.getrandbits(1)
    return ((s << 31) | (e << 23) | m) & M32


def check_counterexample():
    # terms 1, 1e30, -1e30 split (0..1),(1..3):
    #   chain: ((0+1)+1e30)+(-1e30) = 0   (the 1 is absorbed)
    #   naive: 1 + ((0+1e30)+(-1e30)) = 1
    terms = [(f2b(1.0), f2b(1.0)), (f2b(1e30), f2b(1.0)), (f2b(-1e30), f2b(1.0))]
    chain = chain_wgrad(range(3), terms)
    naive = naive_shard_fold([(0, 1), (1, 3)], terms)
    seeded = seeded_shard_chain([(0, 1), (1, 3)], terms)
    assert chain == 0x00000000, hex(chain)
    assert naive == f2b(1.0), hex(naive)
    assert seeded == chain
    print("counterexample: chain=+0, naive fold=1.0, seeded chain=+0  OK")


def check_census(rng, cases=300, batch=32):
    shard_counts = [1, 2, 4, 8, 16, 32, 64]
    naive_mismatch = 0
    for _ in range(cases):
        terms = [(random_bits(rng), random_bits(rng)) for _ in range(batch)]
        chain = chain_wgrad(range(batch), terms)
        if math.isnan(struct.unpack("<f", struct.pack("<I", chain))[0]):
            continue
        any_naive_diff = False
        for s in shard_counts:
            chunks = shard_split(batch, s)
            assert seeded_shard_chain(chunks, terms) == chain, (
                f"seeded chain broke regrouping at shards={s}"
            )
            if s > 1 and naive_shard_fold(chunks, terms) != chain:
                any_naive_diff = True
        if any_naive_diff:
            naive_mismatch += 1
    print(
        f"census: seeded chain bit-identical in {cases}/{cases} random "
        f"batches x shards {shard_counts}; naive fold mismatched the "
        f"canonical chain in {naive_mismatch}/{cases}"
    )
    assert naive_mismatch > 0, "census too tame to distinguish the folds"


def check_conv_row_order(rng, cases=50, batch=8, ohw=9):
    """Conv wgrad rows are r = b*ohw + p (sample-major).  Chunking the
    *samples* at (lo, hi) maps to the contiguous row range
    [lo*ohw, hi*ohw) — so the seeded chain over per-shard row blocks is
    again the global row chain, including empty chunks."""
    for _ in range(cases):
        rows = batch * ohw
        terms = [(random_bits(rng), random_bits(rng)) for _ in range(rows)]
        chain = chain_wgrad(range(rows), terms)
        for s in [1, 2, 3, 5, 8, 16]:
            chunks = [(lo * ohw, hi * ohw) for lo, hi in shard_split(batch, s)]
            assert seeded_shard_chain(chunks, terms) == chain, (
                f"conv row-order regrouping broke at shards={s}"
            )
    print(f"conv row order: seeded chain bit-identical in {cases}/{cases} batches")


def check_bias_fold(rng, cases=100, batch=32):
    """db is a pure pim_add fold over sample rows; the seeded version
    continues the same fold across chunk boundaries."""
    for _ in range(cases):
        deltas = [random_bits(rng) for _ in range(batch)]
        acc = 0
        for d in deltas:
            acc = pim_add_bits(acc, d)
        for s in [1, 2, 4, 8, 16, 32, 64]:
            carry = 0
            for lo, hi in shard_split(batch, s):
                for r in range(lo, hi):
                    carry = pim_add_bits(carry, deltas[r])
            assert carry == acc, f"bias fold regrouping broke at shards={s}"
    print(f"bias fold: seeded chain bit-identical in {cases}/{cases} batches")


# ---- cluster_scaling gate arithmetic (shards=64 < 0.05x shards=1) ----

def proposed_costs():
    """OpCosts::proposed_default(): Table 1 cell, 1T-1R, 28 nm, 1024^2."""
    pitch = math.sqrt(30.0) * 28e-9
    line_len = 1024 * pitch
    c_line = 200e-12 * line_len
    r_line = 2.0e6 * line_len
    t_rc = 0.5 * r_line * c_line
    t_read = 0.25e-9 + t_rc + 0.40e-9
    t_write = (0.28e-9 + 2.0e-9) * 1  # 1T-1R: one write step
    t_search = t_read
    return t_read, t_write, t_search


def check_latency_gate():
    t_read, t_write, t_search = proposed_costs()
    ne, nm = 8, 23
    t_add = (
        (1 + 7 * ne + 7 * nm) * t_read
        + (7 * ne + 7 * nm) * t_write
        + 2 * (nm + 2) * t_search
    )
    t_mul = (2 * nm * nm + 6.5 * nm + 6 * ne + 3) * (t_read + t_write)
    t_mac = t_mul + t_add

    # LeNet-5 per-sample forward MACs and parameter count.
    fwd = 6 * 24 * 24 * 25 + 12 * 8 * 8 * 150 + 192 * 97 + 97 * 10
    p = (150 + 6) + (1800 + 12) + (192 * 97 + 97) + (97 * 10 + 10)
    assert fwd == 221_194 and p == 21_669
    batch, lanes = 32, 32_768

    def sim_latency(shards):
        chunks = shard_split(batch, shards)
        sizes = [hi - lo for lo, hi in chunks]
        if shards == 1:
            waves = -(-(3 * fwd * batch + p) // lanes)
            return waves * t_mac
        active = sum(1 for n in sizes if n > 0)
        max_waves = max(-(-(3 * fwd * n) // lanes) for n in sizes)
        levels = max(1, math.ceil(math.log2(active)))
        reduce_l = levels * -(-p // lanes) * t_add
        hop_waves = -(-(p * 32) // lanes)
        link_l = 2 * levels * hop_waves * t_write
        update_l = -(-p // lanes) * t_mac
        return max_waves * t_mac + reduce_l + link_l + update_l

    l1 = sim_latency(1)
    for s in [2, 4, 8, 16, 32, 64]:
        ls = sim_latency(s)
        print(f"  sim latency shards={s:>2}: {ls*1e6:8.1f} us  ({ls/l1:.4f}x of shards=1)")
    ratio = sim_latency(64) / l1
    assert ratio < 0.05, f"shards=64 gate would fail: {ratio:.4f}"
    print(f"latency gate: shards=64 is {ratio:.4f}x shards=1 (< 0.05)  OK")


def main():
    rng = random.Random(0xC1A5)
    check_counterexample()
    check_census(rng)
    check_conv_row_order(rng)
    check_bias_fold(rng)
    check_latency_gate()
    print("validate_shard_reduce: all checks passed")


if __name__ == "__main__":
    main()
