#!/usr/bin/env python3
"""Compare freshly-emitted bench JSON against the committed baseline.

The benches write ``BENCH_<name>.json`` into the working directory when
run with ``-- --json`` — overwriting the committed baselines — so this
script reads the *committed* version via ``git show HEAD:<file>`` and
compares it to the file on disk (the fresh run).

Gate: one headline entry per bench file — the pooled ``train_step``,
the threads-4 ``gemm_wave`` engine, and the shards-4 ``cluster_scaling``
step — must not regress more than ``--max-regress-pct`` (default 10,
env ``BENCH_REGRESSION_PCT``) versus the committed baseline's
``mean_ns``.  All other shared entries are reported but informational.

Entries present in the fresh output but absent from the committed
baseline are a hard failure: a silently-unknown name means a gate (or a
``metric:`` counter) was added without refreshing the baseline, so
nothing would ever compare it — exactly how the decodes-per-step gate
could rot away unnoticed.  Refresh and commit the ``BENCH_*.json``
whenever a bench grows an entry.

``EXACT_GATES`` entries carry counters in ``mean_ns`` (the PR 8
``decodes per step`` resident-panel counter, committed baseline 0.0)
and must match the baseline bit-for-bit in either direction: any
nonzero fresh value means a steady-state train step re-decoded a weight
panel, which the resident-panel contract forbids.

``CEILING_GATES`` entries carry serving SLO values in ``mean_ns``
(p99 latency in ms, shed+reject percentage) where *lower or equal* is
healthy and growth is the regression: they fail when the fresh value
exceeds the committed baseline by more than ``SERVING_CEILING_PCT``
percent (default 10; CI relaxes for shared-runner noise).  The serving
simulation runs in virtual time, so these are near-deterministic — the
slack only absorbs float summation-order drift, not hardware.

``sparsity`` gates the dense-vs-ratio-0.75 wall-clock *within the fresh
run* (hardware-independent): the PR 10 wave-level block skip must keep
paying ``SPARSITY_MIN_SPEEDUP`` on the host, minus ``SPARSITY_SLACK_PCT``
of runner noise, and its dense-mask bit-identity / steady-state alloc
counters sit under exact zero gates.

``cluster_scaling`` additionally gates shards=2 ≤ shards=1 *within the
fresh run* (hardware-independent, like the ABFT overhead gate): PR 7
replaced the per-sample micrograd lowering with one batched backward
per shard, so splitting the batch across two chips must never cost
wall-clock over one chip.  Before the fix shards=2 ran ~2.8× slower
than shards=1 and was only reported informationally — that anomaly is
gone, and this gate keeps it gone.

Baselines are hardware-dependent: after intentional perf changes (or on
new hardware) re-run the benches with ``-- --json`` and commit the
refreshed ``BENCH_*.json`` files (they are the new baseline).  Set
``BENCH_REGRESSION_SKIP=1`` to bypass the gate entirely.

Usage:
    python3 tools/check_bench_regression.py [--max-regress-pct N]
"""

import argparse
import json
import os
import subprocess
import sys

BENCHES = [
    "BENCH_train_step.json",
    "BENCH_gemm_wave.json",
    "BENCH_cluster_scaling.json",
    "BENCH_fault_tolerance.json",
    "BENCH_serving.json",
    "BENCH_sparsity.json",
]

# The gated headline entry of each bench file.
GATES = {
    "BENCH_train_step.json": "lenet5 train step batch 32 (threads 4, pooled)",
    "BENCH_gemm_wave.json": "gemm engine 128x256 batch 32 (threads 4)",
    "BENCH_cluster_scaling.json": "lenet5 cluster step batch 32 shards 4",
    "BENCH_fault_tolerance.json": "lenet5 fault-free train step batch 32 (threads 4)",
    "BENCH_serving.json": "serving: 100000 open-loop arrivals @ 1.0x offered load (chips 2, healthy)",
    "BENCH_sparsity.json": "mlp-wide train step batch 32 (threads 4, pooled, dense)",
}

# ``metric:`` entries carry verification percentages in ``mean_ns``
# (detection rate, recovered-loss match), not wall-clock — higher is
# better.  Reversed gates fail on any drop below the committed baseline.
REVERSED_GATES = {
    "BENCH_fault_tolerance.json": ["metric: abft detection rate pct"],
}

# ``metric:`` entries where *growth* is the regression (tail latency in
# ms, shed+reject percentages): fail when the fresh value exceeds the
# committed baseline by more than ``SERVING_CEILING_PCT`` percent.
CEILING_GATES = {
    "BENCH_serving.json": [
        "metric: serving p99 ms @1.0x healthy",
        "metric: serving p99 ms @2.0x healthy",
        "metric: serving shed+reject pct @2.0x healthy",
        "metric: serving p99 ms @1.0x one-dead",
        "metric: serving p99 ms @1.0x sparse-0.75",
    ],
}

# ``metric:`` entries that must equal the committed baseline *exactly*
# (counters, not wall-clock — here: bulk weight-panel decode passes in a
# steady-state pooled train step, resident-panel contract value 0.0).
EXACT_GATES = {
    "BENCH_train_step.json": ["metric: decodes per step (threads 4, pooled)"],
    "BENCH_serving.json": [
        "metric: serving unrecovered faults",
        "metric: serving steady-state dispatch allocs",
    ],
    "BENCH_sparsity.json": [
        "metric: sparsity dense-mask bit mismatches",
        "metric: sparsity steady-state allocs (ratio 0.75)",
    ],
}

# Cross-entry gate within the fresh fault_tolerance run: the
# armed-at-zero-rate ABFT step may cost at most this much wall-clock
# over the fault-free step (env ``FAULT_FREE_OVERHEAD_PCT``; CI relaxes
# for shared-runner noise).
FAULT_FREE_ENTRY = "lenet5 fault-free train step batch 32 (threads 4)"
ZERO_RATE_ENTRY = "lenet5 abft-armed zero-rate train step batch 32 (threads 4)"

# Cross-entry gate within the fresh cluster_scaling run: splitting the
# batch across two chips must not cost wall-clock over one chip (the
# PR 7 anomaly fix).  Env ``SHARD2_SLACK_PCT`` grants measurement slack
# on noisy shared runners (default 5%).
SHARDS_1_ENTRY = "lenet5 cluster step batch 32 shards 1"
SHARDS_2_ENTRY = "lenet5 cluster step batch 32 shards 2"

# Cross-entry gate within the fresh sparsity run: the ratio-0.75
# block-sparse step must beat the dense step by ``SPARSITY_MIN_SPEEDUP``
# (default 1.3x, mirroring the bench's in-binary gate), with
# ``SPARSITY_SLACK_PCT`` percent of measurement slack for noisy shared
# runners (default 10 -> effective floor 1.3 * 0.9 = 1.17x).  Hardware-
# independent like the shards gate: both entries come from the same run.
SPARSITY_DENSE_ENTRY = "mlp-wide train step batch 32 (threads 4, pooled, dense)"
SPARSITY_SPARSE_ENTRY = (
    "mlp-wide train step batch 32 (threads 4, pooled, sparse block=4 ratio=0.75)"
)


def load_committed(path):
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"],
            capture_output=True,
            text=True,
            check=True,
        ).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    return json.loads(out)


def load_fresh(path):
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def by_name(entries):
    return {e["name"]: e for e in entries or []}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--max-regress-pct",
        type=float,
        default=float(os.environ.get("BENCH_REGRESSION_PCT", "10")),
        help="fail when the gated entry is this much slower than baseline",
    )
    args = ap.parse_args()

    if os.environ.get("BENCH_REGRESSION_SKIP") == "1":
        print("BENCH_REGRESSION_SKIP=1: skipping bench regression gate")
        return 0

    failures = []
    for path in BENCHES:
        base = by_name(load_committed(path))
        fresh = by_name(load_fresh(path))
        if not base:
            print(f"{path}: no committed baseline (skipping)")
            continue
        if not fresh:
            print(f"{path}: bench output missing (did the bench run with -- --json?)")
            failures.append(f"{path} missing fresh output")
            continue
        gate_name = GATES.get(path)
        reversed_names = REVERSED_GATES.get(path, [])
        exact_names = EXACT_GATES.get(path, [])
        ceiling_names = CEILING_GATES.get(path, [])
        ceiling_pct = float(os.environ.get("SERVING_CEILING_PCT", "10"))
        # Unknown fresh entries: a name the committed baseline has never
        # seen can never be compared, so a new gate added without a
        # baseline refresh would silently pass forever.
        for name in sorted(fresh.keys() - base.keys()):
            failures.append(
                f"{path}: fresh entry '{name}' is absent from the committed "
                f"baseline (refresh with `cargo bench -- --json` and commit)"
            )
        for name in sorted(base.keys() & fresh.keys()):
            b, f = base[name]["mean_ns"], fresh[name]["mean_ns"]
            delta = (f - b) / b * 100.0 if b else 0.0
            if name.startswith("metric: "):
                gated = (
                    name in reversed_names
                    or name in exact_names
                    or name in ceiling_names
                )
                tag = "GATE" if gated else "info"
                print(f"[{tag}] {name}: baseline {b:.1f}, fresh {f:.1f} ({delta:+.1f}%)")
                if name in reversed_names and f < b - 1e-9:
                    failures.append(
                        f"{name}: dropped to {f:.1f} from baseline {b:.1f} (must not regress)"
                    )
                if name in exact_names and abs(f - b) > 1e-9:
                    failures.append(
                        f"{name}: fresh {f:.1f} != committed {b:.1f} (exact gate; a "
                        f"nonzero counter means a zero-contract broke)"
                    )
                if name in ceiling_names and f > b * (1.0 + ceiling_pct / 100.0):
                    failures.append(
                        f"{name}: fresh {f:.2f} exceeds baseline {b:.2f} "
                        f"ceiling (+{ceiling_pct}%)"
                    )
                continue
            gated = name == gate_name
            tag = "GATE" if gated else "info"
            print(f"[{tag}] {name}: baseline {b/1e6:.2f} ms, fresh {f/1e6:.2f} ms ({delta:+.1f}%)")
            if gated and delta > args.max_regress_pct:
                failures.append(
                    f"{name}: {delta:+.1f}% vs baseline (limit +{args.max_regress_pct}%)"
                )
        if gate_name is not None:
            if gate_name not in base:
                failures.append(f"{path}: committed baseline lacks gated entry '{gate_name}'")
            if fresh and gate_name not in fresh:
                failures.append(f"{path}: fresh run lacks gated entry '{gate_name}'")
        for name in reversed_names:
            if name not in base:
                failures.append(f"{path}: committed baseline lacks reversed gate '{name}'")
            if fresh and name not in fresh:
                failures.append(f"{path}: fresh run lacks reversed gate '{name}'")
        for name in exact_names:
            if name not in base:
                failures.append(f"{path}: committed baseline lacks exact gate '{name}'")
            if fresh and name not in fresh:
                failures.append(f"{path}: fresh run lacks exact gate '{name}'")
        for name in ceiling_names:
            if name not in base:
                failures.append(f"{path}: committed baseline lacks ceiling gate '{name}'")
            if fresh and name not in fresh:
                failures.append(f"{path}: fresh run lacks ceiling gate '{name}'")
        # Fault-free ABFT overhead: compare the two fresh entries of the
        # same run (hardware-independent, unlike the baselines).
        if path == "BENCH_fault_tolerance.json" and fresh:
            limit = float(os.environ.get("FAULT_FREE_OVERHEAD_PCT", "5"))
            if FAULT_FREE_ENTRY in fresh and ZERO_RATE_ENTRY in fresh:
                clean = fresh[FAULT_FREE_ENTRY]["mean_ns"]
                armed = fresh[ZERO_RATE_ENTRY]["mean_ns"]
                pct = (armed - clean) / clean * 100.0 if clean else 0.0
                print(
                    f"[GATE] abft fault-free overhead: {pct:+.2f}% "
                    f"(armed-at-zero vs fault-free, limit +{limit}%)"
                )
                if pct > limit:
                    failures.append(
                        f"abft fault-free overhead {pct:+.2f}% exceeds +{limit}%"
                    )
            else:
                failures.append(
                    f"{path}: fresh run lacks the fault-free/zero-rate entry pair"
                )
        # Shards=2 anomaly gate: compare the two fresh entries of the
        # same run (hardware-independent, unlike the baselines).
        if path == "BENCH_cluster_scaling.json" and fresh:
            slack = float(os.environ.get("SHARD2_SLACK_PCT", "5"))
            if SHARDS_1_ENTRY in fresh and SHARDS_2_ENTRY in fresh:
                s1 = fresh[SHARDS_1_ENTRY]["mean_ns"]
                s2 = fresh[SHARDS_2_ENTRY]["mean_ns"]
                pct = (s2 - s1) / s1 * 100.0 if s1 else 0.0
                print(
                    f"[GATE] shards=2 vs shards=1 wall-clock: {pct:+.2f}% "
                    f"(must be <= +{slack}%)"
                )
                if pct > slack:
                    failures.append(
                        f"shards=2 step is {pct:+.2f}% vs shards=1 "
                        f"(limit +{slack}%; the PR 7 anomaly fix must hold)"
                    )
            else:
                failures.append(
                    f"{path}: fresh run lacks the shards=1/shards=2 entry pair"
                )
        # Sparse-vs-dense speedup gate: compare the two fresh entries of
        # the same sparsity run (hardware-independent).
        if path == "BENCH_sparsity.json" and fresh:
            min_speedup = float(os.environ.get("SPARSITY_MIN_SPEEDUP", "1.3"))
            slack = float(os.environ.get("SPARSITY_SLACK_PCT", "10"))
            floor = min_speedup * (1.0 - slack / 100.0)
            if SPARSITY_DENSE_ENTRY in fresh and SPARSITY_SPARSE_ENTRY in fresh:
                dense = fresh[SPARSITY_DENSE_ENTRY]["mean_ns"]
                sparse = fresh[SPARSITY_SPARSE_ENTRY]["mean_ns"]
                speedup = dense / sparse if sparse else 0.0
                print(
                    f"[GATE] sparse ratio=0.75 vs dense wall-clock: {speedup:.2f}x "
                    f"(must be >= {floor:.2f}x)"
                )
                if speedup < floor:
                    failures.append(
                        f"block-sparse step speedup {speedup:.2f}x below the "
                        f"{floor:.2f}x floor ({min_speedup}x minus {slack}% slack); "
                        f"wave-level skips must pay on the host too"
                    )
            else:
                failures.append(
                    f"{path}: fresh run lacks the dense/sparse-0.75 entry pair"
                )

    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench regression gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
